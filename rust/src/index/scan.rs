//! The ADC scan hot path.
//!
//! `scan_lut_topk` is the specialized f32 LUT loop (the overwhelmingly
//! common case: PQ/OPQ/RVQ/LSQ/UNQ all scan through `Lut::Tables`);
//! `scan_lut_topk_u16` / `scan_lut_topk_u8` / `scan_lut_topk_u4` are the
//! blocked integer fast-scan kernels (select with quantized-LUT integer
//! scores over the [`super::packed`] layout, then exactly re-score the
//! survivors in f32 — rust/DESIGN.md §6); `scan_topk` dispatches,
//! falling back to the generic `Lut::score` for the lattice's direct
//! dot scoring.
//!
//! Each integer kernel exists twice: the scalar loop below (the
//! property-test oracle, kept verbatim, pinned by `UNQ_FORCE_SCALAR=1`)
//! and a [`super::simd`] block accumulator selected by a cached runtime
//! CPU probe (rust/DESIGN.md §9).  Both produce bit-identical integer
//! lane sums, so dispatch can never change a result.  The optional
//! 1-bit sketch pre-filter ([`scan_range_topk_prefiltered`]) prunes by
//! Hamming distance before exact scoring.
//!
//! Performance notes (see `rust/DESIGN.md` §2/§6 for measurements):
//! * the per-row loop over `stride` table lookups is unrolled by the
//!   compiler for the fixed strides we exercise; the LUT layout is
//!   position-major (`tables[j·K + code[j]]`, the contract documented on
//!   [`Lut::Tables`]) so all lookups hit one small table
//!   (8–17 rows × 256 × 4 B ≤ 17 KB, L1-resident — half that at u16,
//!   a quarter at u8);
//! * the bounded heap makes the common case (candidate worse than the
//!   current k-th best) a single compare-and-skip;
//! * the f32 kernel accumulates in plain f32 — identical to the paper's
//!   setup; the integer kernels accumulate u32 lanes over 32-row blocks
//!   and re-score the surviving candidate set exactly.

use crate::linalg::TopK;
use crate::obs;
use crate::quant::{Lut, QuantizedLut, U4_ROW};

use super::filter::FilterBitmap;
use super::packed::BLOCK;
use super::simd;
use super::CompressedIndex;

/// Scan the whole index with a table LUT, returning the k smallest
/// `(score, id)` pairs sorted ascending.  A `filter` bitmap prunes rows
/// *inside* selection: non-admitted rows are never scored into the
/// heap, so the result is exactly the scan of the admitted subset
/// (rust/DESIGN.md §13).
pub fn scan_lut_topk(tables: &[f32], k_width: usize, bias: f32,
                     index: &CompressedIndex, lo: usize, hi: usize,
                     k: usize, filter: Option<&FilterBitmap>)
                     -> Vec<(f32, u32)> {
    let stride = index.stride;
    // never size the heap past the range: k comes from callers (and
    // ultimately the wire), the row count is ground truth
    let mut top = TopK::new(k.min(hi - lo).max(1));
    let mut worst = f32::INFINITY;
    let codes = &index.codes[lo * stride..hi * stride];
    if let Some(f) = filter {
        // filtered path: a plain per-row loop (each quad lane below
        // accumulates its row independently and in the same position
        // order, so per-row sums are bit-identical between the paths)
        for row in 0..hi - lo {
            if !f.is_admitted(lo + row) {
                continue;
            }
            let code = &codes[row * stride..(row + 1) * stride];
            let mut acc = bias;
            for (j, &c) in code.iter().enumerate() {
                // SAFETY: tables is (stride, k_width); code bytes <
                // k_width by construction (encoders emit ids < K)
                acc += unsafe {
                    *tables.get_unchecked(j * k_width + c as usize)
                };
            }
            if acc < worst {
                top.push(acc, (lo + row) as u32);
                worst = top.worst();
            }
        }
        return top.into_sorted();
    }
    // 4-row software pipeline: the per-row table gathers are independent,
    // so interleaving four rows gives the core 4× the memory-level
    // parallelism on the (L2-missing) code stream — see rust/DESIGN.md §2
    // for the measured effect at n = 1M.
    let n_rows = hi - lo;
    let quads = n_rows / 4;
    for qi in 0..quads {
        let base0 = qi * 4 * stride;
        let (mut a0, mut a1, mut a2, mut a3) = (bias, bias, bias, bias);
        for j in 0..stride {
            // SAFETY: tables is (stride, k_width); code bytes < k_width by
            // construction (encoders emit ids < K)
            unsafe {
                let t = tables.as_ptr().add(j * k_width);
                a0 += *t.add(*codes.get_unchecked(base0 + j) as usize);
                a1 += *t.add(*codes.get_unchecked(base0 + stride + j) as usize);
                a2 += *t.add(*codes.get_unchecked(base0 + 2 * stride + j) as usize);
                a3 += *t.add(*codes.get_unchecked(base0 + 3 * stride + j) as usize);
            }
        }
        let row = lo + qi * 4;
        if a0 < worst {
            top.push(a0, row as u32);
            worst = top.worst();
        }
        if a1 < worst {
            top.push(a1, (row + 1) as u32);
            worst = top.worst();
        }
        if a2 < worst {
            top.push(a2, (row + 2) as u32);
            worst = top.worst();
        }
        if a3 < worst {
            top.push(a3, (row + 3) as u32);
            worst = top.worst();
        }
    }
    for row in quads * 4..n_rows {
        let code = &codes[row * stride..(row + 1) * stride];
        let mut acc = bias;
        for (j, &c) in code.iter().enumerate() {
            // SAFETY: tables is (stride, k_width); code bytes < k_width
            // by construction (encoders emit ids < K)
            acc += unsafe { *tables.get_unchecked(j * k_width + c as usize) };
        }
        if acc < worst {
            top.push(acc, (lo + row) as u32);
            worst = top.worst();
        }
    }
    top.into_sorted()
}

/// Blocked u16 fast-scan over `[lo, hi)`: integer candidate selection
/// with `qlut`, exact f32 re-score of the survivors with `lut`.
///
/// Returned pairs carry **exact f32 scores** (so cross-shard merges
/// compare in the same domain as the f32 kernel), sorted ascending by
/// `(score, id)`.  The returned ids equal [`scan_lut_topk`]'s whenever
/// the f32 margin at the k-th boundary exceeds twice the quantization
/// error bound `stride · step / 2` (DESIGN.md §6); inside that margin
/// the integer selection may swap boundary candidates.
pub fn scan_lut_topk_u16(qlut: &QuantizedLut, lut: &Lut,
                         index: &CompressedIndex, lo: usize, hi: usize,
                         k: usize, filter: Option<&FilterBitmap>)
                         -> Vec<(f32, u32)> {
    scan_lut_topk_u16_forced(qlut, lut, index, lo, hi, k, filter,
                             simd::scalar_forced())
}

/// [`scan_lut_topk_u16`] with dispatch pinned by the caller: tests pass
/// explicit `force_scalar` values so SIMD-vs-oracle comparisons don't
/// depend on process-wide environment state.
pub fn scan_lut_topk_u16_forced(qlut: &QuantizedLut, lut: &Lut,
                                index: &CompressedIndex, lo: usize,
                                hi: usize, k: usize,
                                filter: Option<&FilterBitmap>,
                                force_scalar: bool)
                                -> Vec<(f32, u32)> {
    match qlut {
        QuantizedLut::U16 { m, k: kw, tables, .. } => {
            if force_scalar || !simd::int_kernel_active() {
                obs::global().simd_dispatch_scalar.inc();
                scan_blocked_int(tables, *m, *kw, lut, index, lo, hi, k,
                                 filter)
            } else {
                obs::global().simd_dispatch_simd.inc();
                scan_blocked_int_simd(tables, *m, *kw, lut, index, lo, hi,
                                      k, filter)
            }
        }
        _ => panic!("scan_lut_topk_u16 requires a u16-quantized LUT"),
    }
}

/// Blocked u8 fast-scan over `[lo, hi)` — same contract as
/// [`scan_lut_topk_u16`] with a coarser (one-byte) entry width.
pub fn scan_lut_topk_u8(qlut: &QuantizedLut, lut: &Lut,
                        index: &CompressedIndex, lo: usize, hi: usize,
                        k: usize, filter: Option<&FilterBitmap>)
                        -> Vec<(f32, u32)> {
    scan_lut_topk_u8_forced(qlut, lut, index, lo, hi, k, filter,
                            simd::scalar_forced())
}

/// [`scan_lut_topk_u8`] with caller-pinned dispatch (see
/// [`scan_lut_topk_u16_forced`]).
pub fn scan_lut_topk_u8_forced(qlut: &QuantizedLut, lut: &Lut,
                               index: &CompressedIndex, lo: usize,
                               hi: usize, k: usize,
                               filter: Option<&FilterBitmap>,
                               force_scalar: bool)
                               -> Vec<(f32, u32)> {
    match qlut {
        QuantizedLut::U8 { m, k: kw, tables, .. } => {
            if force_scalar || !simd::int_kernel_active() {
                obs::global().simd_dispatch_scalar.inc();
                scan_blocked_int(tables, *m, *kw, lut, index, lo, hi, k,
                                 filter)
            } else {
                obs::global().simd_dispatch_simd.inc();
                scan_blocked_int_simd(tables, *m, *kw, lut, index, lo, hi,
                                      k, filter)
            }
        }
        _ => panic!("scan_lut_topk_u8 requires a u8-quantized LUT"),
    }
}

/// Blocked 4-bit fast-scan over `[lo, hi)` — same contract as
/// [`scan_lut_topk_u16`].  Table rows are a fixed [`U4_ROW`] = 16
/// entries wide (one vector register), so the scalar oracle is the
/// shared blocked kernel at `kw = 16` and the SIMD path gathers
/// in-register with PSHUFB/TBL.
pub fn scan_lut_topk_u4(qlut: &QuantizedLut, lut: &Lut,
                        index: &CompressedIndex, lo: usize, hi: usize,
                        k: usize, filter: Option<&FilterBitmap>)
                        -> Vec<(f32, u32)> {
    scan_lut_topk_u4_forced(qlut, lut, index, lo, hi, k, filter,
                            simd::scalar_forced())
}

/// [`scan_lut_topk_u4`] with caller-pinned dispatch (see
/// [`scan_lut_topk_u16_forced`]).
pub fn scan_lut_topk_u4_forced(qlut: &QuantizedLut, lut: &Lut,
                               index: &CompressedIndex, lo: usize,
                               hi: usize, k: usize,
                               filter: Option<&FilterBitmap>,
                               force_scalar: bool)
                               -> Vec<(f32, u32)> {
    match qlut {
        QuantizedLut::U4 { m, tables, .. } => {
            if force_scalar || !simd::u4_kernel_active() {
                obs::global().simd_dispatch_scalar.inc();
                scan_blocked_int(tables, *m, U4_ROW, lut, index, lo, hi, k,
                                 filter)
            } else {
                obs::global().simd_dispatch_simd.inc();
                scan_blocked_u4_simd(tables, *m, lut, index, lo, hi, k,
                                     filter)
            }
        }
        _ => panic!("scan_lut_topk_u4 requires a u4-quantized LUT"),
    }
}

/// The shared blocked integer kernel: 32 u32 accumulator lanes walk one
/// quantized table row across a whole block per step, so every load on
/// the code stream is sequential (the packed layout) and the table row
/// is register/L1-hot.  Integer scores are ≤ `stride · (2¹⁶ − 1) < 2²⁴`,
/// hence exactly representable as f32 — the shared lexicographic [`TopK`]
/// selects under `(int score, id)` without a second heap type.  Falls
/// back to an on-the-fly 32-row transpose when the index carries no
/// packed mirror (identical results, more memory traffic).
fn scan_blocked_int<T: Copy + Into<u32>>(
    qtables: &[T], m: usize, kw: usize, lut: &Lut, index: &CompressedIndex,
    lo: usize, hi: usize, k: usize, filter: Option<&FilterBitmap>)
    -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    if lo >= hi {
        return Vec::new();
    }
    let stride = index.stride;
    debug_assert_eq!(m, stride, "quantized LUT rows must match index stride");
    debug_assert_eq!(qtables.len(), m * kw);
    let mut top = TopK::new(k.min(hi - lo).max(1));
    let mut worst = f32::INFINITY;
    // transpose buffer for the unpacked fallback, allocated only when
    // that path actually runs — the packed fast path stays allocation-free
    let mut scratch = Vec::new();
    let b0 = lo / BLOCK;
    let b1 = hi.div_ceil(BLOCK);
    for b in b0..b1 {
        let row0 = b * BLOCK;
        let blk: &[u8] = match &index.packed {
            Some(p) => {
                debug_assert_eq!(p.n, index.n);
                p.block(b)
            }
            None => {
                // gather this block position-major on the fly; pad lanes
                // with byte 0 (a valid codeword — padded scores are
                // computed but never emitted)
                if scratch.is_empty() {
                    scratch.resize(stride * BLOCK, 0u8);
                }
                let rows = (index.n - row0).min(BLOCK);
                for j in 0..stride {
                    for r in 0..rows {
                        scratch[j * BLOCK + r] =
                            index.codes[(row0 + r) * stride + j];
                    }
                    for r in rows..BLOCK {
                        scratch[j * BLOCK + r] = 0;
                    }
                }
                &scratch[..]
            }
        };
        let mut acc = [0u32; BLOCK];
        for j in 0..stride {
            // SAFETY: qtables is (stride, k_width); code bytes < k_width
            // by construction (encoders emit ids < K, pad lanes are 0)
            unsafe {
                let t = qtables.as_ptr().add(j * kw);
                let lane = blk.as_ptr().add(j * BLOCK);
                for (r, a) in acc.iter_mut().enumerate() {
                    *a += <T as Into<u32>>::into(
                        *t.add(*lane.add(r) as usize));
                }
            }
        }
        let rlo = lo.max(row0) - row0;
        let rhi = hi.min(row0 + BLOCK) - row0;
        for (r, &a) in acc.iter().enumerate().take(rhi).skip(rlo) {
            // filtered rows never enter integer selection, so the
            // survivor set equals the admitted-subset scan's exactly
            if let Some(f) = filter {
                if !f.is_admitted(row0 + r) {
                    continue;
                }
            }
            let s = a as f32;
            // <= admits k-th-boundary score ties so the lexicographic
            // heap can keep the smaller id deterministically
            if s <= worst {
                top.push(s, (row0 + r) as u32);
                worst = top.worst();
            }
        }
    }
    // exact re-score: replace integer scores with the f32 LUT scores of
    // the surviving candidate set and re-rank under (score, id)
    let mut out: Vec<(f32, u32)> = top
        .into_sorted()
        .into_iter()
        .map(|(_, id)| (lut.score(index.code(id as usize)), id))
        .collect();
    out.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).expect("ADC scores are not NaN")
            .then(a.1.cmp(&b.1))
    });
    out
}

/// On-the-fly position-major transpose of one 32-row block for indexes
/// without a packed mirror (shared by the SIMD drivers; the scalar
/// kernel keeps its own inline copy verbatim).  Pads missing lanes with
/// byte 0 — a valid codeword id, computed but never emitted.
fn gather_block(index: &CompressedIndex, row0: usize,
                scratch: &mut Vec<u8>) {
    let stride = index.stride;
    if scratch.is_empty() {
        scratch.resize(stride * BLOCK, 0u8);
    }
    let rows = (index.n - row0).min(BLOCK);
    for j in 0..stride {
        for r in 0..rows {
            scratch[j * BLOCK + r] = index.codes[(row0 + r) * stride + j];
        }
        for r in rows..BLOCK {
            scratch[j * BLOCK + r] = 0;
        }
    }
}

/// Push one block's accumulator lanes into the running top-k (rows
/// `[rlo, rhi)` of the block are live; `<=` admits k-th-boundary score
/// ties so the lexicographic heap keeps the smaller id).
#[inline]
fn emit_block(acc: &[u32; BLOCK], row0: usize, rlo: usize, rhi: usize,
              filter: Option<&FilterBitmap>, top: &mut TopK,
              worst: &mut f32) {
    for (r, &a) in acc.iter().enumerate().take(rhi).skip(rlo) {
        if let Some(f) = filter {
            if !f.is_admitted(row0 + r) {
                continue;
            }
        }
        let s = a as f32;
        if s <= *worst {
            top.push(s, (row0 + r) as u32);
            *worst = top.worst();
        }
    }
}

/// Exact re-score of an integer-selected candidate set: replace integer
/// scores with the f32 LUT scores and re-rank under `(score, id)`.
fn rescore_exact(top: TopK, lut: &Lut, index: &CompressedIndex)
                 -> Vec<(f32, u32)> {
    let mut out: Vec<(f32, u32)> = top
        .into_sorted()
        .into_iter()
        .map(|(_, id)| (lut.score(index.code(id as usize)), id))
        .collect();
    out.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).expect("ADC scores are not NaN")
            .then(a.1.cmp(&b.1))
    });
    out
}

/// SIMD twin of [`scan_blocked_int`]: identical block walk, emit, and
/// exact re-score; only the 32-lane accumulation is replaced by the
/// hardware-gather kernel.  The quantized tables are widened to u32
/// once per scan call (≤ 17 rows × 256 × 4 B, L1-resident) so one
/// gather shape serves both entry widths.  Integer lane sums are
/// bit-identical to the scalar kernel (u32 adds reassociate freely),
/// so results match the oracle exactly — the property tests pin this.
fn scan_blocked_int_simd<T: Copy + Into<u32>>(
    qtables: &[T], m: usize, kw: usize, lut: &Lut, index: &CompressedIndex,
    lo: usize, hi: usize, k: usize, filter: Option<&FilterBitmap>)
    -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    if lo >= hi {
        return Vec::new();
    }
    let stride = index.stride;
    debug_assert_eq!(m, stride, "quantized LUT rows must match index stride");
    let widened: Vec<u32> = qtables.iter().map(|&t| t.into()).collect();
    let mut top = TopK::new(k.min(hi - lo).max(1));
    let mut worst = f32::INFINITY;
    let mut scratch = Vec::new();
    let b0 = lo / BLOCK;
    let b1 = hi.div_ceil(BLOCK);
    for b in b0..b1 {
        let row0 = b * BLOCK;
        let blk: &[u8] = match &index.packed {
            Some(p) => {
                debug_assert_eq!(p.n, index.n);
                p.block(b)
            }
            None => {
                gather_block(index, row0, &mut scratch);
                &scratch[..]
            }
        };
        let mut acc = [0u32; BLOCK];
        simd::accumulate_widened(&widened, kw, stride, blk, &mut acc);
        let rlo = lo.max(row0) - row0;
        let rhi = hi.min(row0 + BLOCK) - row0;
        emit_block(&acc, row0, rlo, rhi, filter, &mut top, &mut worst);
    }
    rescore_exact(top, lut, index)
}

/// SIMD 4-bit driver: in-register PSHUFB/TBL gather against the fixed
/// 16-entry table rows, preferring the packed nibble mirror (half the
/// code-stream traffic) and falling back to byte-per-code blocks.
fn scan_blocked_u4_simd(tables: &[u8], m: usize, lut: &Lut,
                        index: &CompressedIndex, lo: usize, hi: usize,
                        k: usize, filter: Option<&FilterBitmap>)
                        -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    if lo >= hi {
        return Vec::new();
    }
    let stride = index.stride;
    debug_assert_eq!(m, stride, "quantized LUT rows must match index stride");
    debug_assert_eq!(tables.len(), m * U4_ROW);
    let mut top = TopK::new(k.min(hi - lo).max(1));
    let mut worst = f32::INFINITY;
    let mut scratch = Vec::new();
    let b0 = lo / BLOCK;
    let b1 = hi.div_ceil(BLOCK);
    for b in b0..b1 {
        let row0 = b * BLOCK;
        let mut acc = [0u32; BLOCK];
        match &index.packed {
            Some(p) => {
                debug_assert_eq!(p.n, index.n);
                match p.nibble_block(b) {
                    Some(nib) => simd::accumulate_u4_nibbles(
                        tables, stride, nib, &mut acc),
                    None => simd::accumulate_u4_bytes(
                        tables, stride, p.block(b), &mut acc),
                }
            }
            None => {
                gather_block(index, row0, &mut scratch);
                simd::accumulate_u4_bytes(tables, stride, &scratch,
                                          &mut acc);
            }
        }
        let rlo = lo.max(row0) - row0;
        let rhi = hi.min(row0 + BLOCK) - row0;
        emit_block(&acc, row0, rlo, rhi, filter, &mut top, &mut worst);
    }
    rescore_exact(top, lut, index)
}

/// Generic scan via `Lut::score` (used by the lattice direct path).
pub fn scan_generic_topk(lut: &Lut, index: &CompressedIndex, lo: usize,
                         hi: usize, k: usize,
                         filter: Option<&FilterBitmap>) -> Vec<(f32, u32)> {
    let mut top = TopK::new(k.min(hi.saturating_sub(lo)).max(1));
    let mut worst = f32::INFINITY;
    for i in lo..hi {
        if let Some(f) = filter {
            if !f.is_admitted(i) {
                continue;
            }
        }
        let s = lut.score(index.code(i));
        if s < worst {
            top.push(s, i as u32);
            worst = top.worst();
        }
    }
    top.into_sorted()
}

/// Dispatching scan over the full index.
pub fn scan_topk(lut: &Lut, index: &CompressedIndex, k: usize)
                 -> Vec<(f32, u32)> {
    scan_range_topk(lut, index, 0, index.n, k, None)
}

/// Dispatching scan over `[lo, hi)` — the shard work unit the batch
/// executor (`exec::plan`) fans out as one task per `(query, shard)`.
pub fn scan_range_topk(lut: &Lut, index: &CompressedIndex, lo: usize,
                       hi: usize, k: usize,
                       filter: Option<&FilterBitmap>) -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    match lut {
        Lut::Tables { m, k: kw, tables, bias } => {
            debug_assert_eq!(*m, index.stride,
                             "LUT rows must match index stride");
            scan_lut_topk(tables, *kw, *bias, index, lo, hi, k, filter)
        }
        Lut::Direct { .. } => {
            scan_generic_topk(lut, index, lo, hi, k, filter)
        }
    }
}

/// Precision-dispatching range scan: the blocked integer kernel when a
/// quantized LUT is supplied (only `Lut::Tables` quantizes — the
/// executor passes `None` for `ScanPrecision::F32` and for direct-scored
/// LUTs, which fall back to the exact f32 path).
pub fn scan_range_topk_prec(lut: &Lut, qlut: Option<&QuantizedLut>,
                            index: &CompressedIndex, lo: usize, hi: usize,
                            k: usize, filter: Option<&FilterBitmap>)
                            -> Vec<(f32, u32)> {
    scan_range_topk_prec_forced(lut, qlut, index, lo, hi, k, filter,
                                simd::scalar_forced())
}

/// [`scan_range_topk_prec`] with dispatch pinned by the caller (test
/// and bench entry: compare SIMD and the scalar oracle in one process
/// without touching environment state).
pub fn scan_range_topk_prec_forced(lut: &Lut, qlut: Option<&QuantizedLut>,
                                   index: &CompressedIndex, lo: usize,
                                   hi: usize, k: usize,
                                   filter: Option<&FilterBitmap>,
                                   force_scalar: bool)
                                   -> Vec<(f32, u32)> {
    match qlut {
        Some(q @ QuantizedLut::U16 { .. }) => {
            scan_lut_topk_u16_forced(q, lut, index, lo, hi, k, filter,
                                     force_scalar)
        }
        Some(q @ QuantizedLut::U8 { .. }) => {
            scan_lut_topk_u8_forced(q, lut, index, lo, hi, k, filter,
                                    force_scalar)
        }
        Some(q @ QuantizedLut::U4 { .. }) => {
            scan_lut_topk_u4_forced(q, lut, index, lo, hi, k, filter,
                                    force_scalar)
        }
        None => scan_range_topk(lut, index, lo, hi, k, filter),
    }
}

/// Hamming-prune `[lo, hi)` against a query sketch, keeping (at least)
/// the `keep` rows nearest in sketch space: a histogram over the 65
/// possible distances picks the smallest threshold whose cumulative
/// count reaches `keep`, then every row at or under it survives.
/// Returned ids are ascending.  The threshold is per-range, so ties at
/// the boundary over-admit rather than under-admit — pruning never cuts
/// below `keep` survivors (unless the range itself is smaller).
pub fn prefilter_survivors(sketches: &[u64], qsketch: u64, lo: usize,
                           hi: usize, keep: usize) -> Vec<u32> {
    let window = &sketches[lo..hi];
    let mut hist = [0u32; 65];
    for &s in window {
        hist[(s ^ qsketch).count_ones() as usize] += 1;
    }
    let mut cum = 0usize;
    let mut thresh = 64usize;
    for (d, &c) in hist.iter().enumerate() {
        cum += c as usize;
        if cum >= keep {
            thresh = d;
            break;
        }
    }
    let mut out = Vec::with_capacity(cum);
    for (i, &s) in window.iter().enumerate() {
        if (s ^ qsketch).count_ones() as usize <= thresh {
            out.push((lo + i) as u32);
        }
    }
    out
}

/// Pre-filtered range scan (rust/DESIGN.md §9): prune `[lo, hi)` to
/// `max(k · margin, k)` sketch-nearest survivors by XOR+popcount, then
/// score only the survivors **exactly in f32**.  Whenever the true
/// top-k all survive the prune — guaranteed when `keep ≥ hi − lo`, and
/// what the over-fetch margin buys statistically otherwise — the result
/// is bit-identical to [`scan_range_topk`]; survivors are never scored
/// approximately, so the pre-filter composes with the rerank contract
/// unchanged.
pub fn scan_range_topk_prefiltered(lut: &Lut, index: &CompressedIndex,
                                   sketches: &[u64], qsketch: u64,
                                   lo: usize, hi: usize, k: usize,
                                   margin: usize,
                                   filter: Option<&FilterBitmap>)
                                   -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    if lo >= hi {
        return Vec::new();
    }
    debug_assert_eq!(sketches.len(), index.n);
    let keep = k.saturating_mul(margin).max(k);
    if keep >= hi - lo {
        return scan_range_topk(lut, index, lo, hi, k, filter);
    }
    let survivors = {
        let mut span = crate::span!("prefilter");
        let survivors =
            prefilter_survivors(sketches, qsketch, lo, hi, keep);
        let reg = obs::global();
        reg.prefilter_admitted.add(survivors.len() as u64);
        reg.prefilter_rejected
            .add(((hi - lo) - survivors.len()) as u64);
        span.add_rows(survivors.len() as u64);
        survivors
    };
    let mut span = crate::span!("rescore");
    span.add_rows(survivors.len() as u64);
    let mut top = TopK::new(k.min(survivors.len()).max(1));
    let mut worst = f32::INFINITY;
    for id in survivors {
        // the metadata filter composes after the sketch prune: only
        // admitted survivors are scored into the heap
        if let Some(f) = filter {
            if !f.is_admitted(id as usize) {
                continue;
            }
        }
        let s = lut.score(index.code(id as usize));
        if s < worst {
            top.push(s, id);
            worst = top.worst();
        }
    }
    top.into_sorted()
}

/// Merge several per-shard top-k lists into a global top-k.
pub fn merge_topk(mut parts: Vec<Vec<(f32, u32)>>, k: usize) -> Vec<(f32, u32)> {
    let total: usize = parts.iter().map(Vec::len).sum();
    let mut top = TopK::new(k.min(total).max(1));
    for part in parts.drain(..) {
        for (s, id) in part {
            top.push(s, id);
        }
    }
    top.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::SplitMix64};

    fn mk_index(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> = (0..n * stride).map(|_| rng.below(256) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut(stride: usize, seed: u64) -> (Vec<f32>, Lut) {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 256).map(|_| rng.next_f32() * 10.0).collect();
        let lut = Lut::Tables { m: stride, k: 256, tables: tables.clone(),
                                bias: 1.5 };
        (tables, lut)
    }

    #[test]
    fn scan_matches_naive_argsort() {
        let idx = mk_index(500, 8, 1);
        let (_, lut) = mk_lut(8, 2);
        let got = scan_topk(&lut, &idx, 10);
        // naive
        let mut all: Vec<(f32, u32)> = (0..500)
            .map(|i| (lut.score(idx.code(i)), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<u32> = all[..10].iter().map(|p| p.1).collect();
        let got_ids: Vec<u32> = got.iter().map(|p| p.1).collect();
        assert_eq!(got_ids, want);
    }

    #[test]
    fn sharded_scan_merge_equals_full_scan() {
        let idx = mk_index(1000, 9, 3);
        let (_, lut) = mk_lut(9, 4);
        let full = scan_topk(&lut, &idx, 25);
        let parts = vec![
            scan_range_topk(&lut, &idx, 0, 400, 25, None),
            scan_range_topk(&lut, &idx, 400, 700, 25, None),
            scan_range_topk(&lut, &idx, 700, 1000, 25, None),
        ];
        let merged = merge_topk(parts, 25);
        assert_eq!(full.iter().map(|p| p.1).collect::<Vec<_>>(),
                   merged.iter().map(|p| p.1).collect::<Vec<_>>());
    }

    #[test]
    fn prop_scan_is_exact_selection() {
        // property over random tables/codes/sizes: scan == argsort prefix
        prop::forall_ok(
            99,
            25,
            |r: &mut SplitMix64| {
                let n = 20 + r.below(300);
                let stride = 1 + r.below(16);
                let k = 1 + r.below(20);
                (n, stride, k, r.next_u64())
            },
            |&(n, stride, k, seed)| {
                let idx = mk_index(n, stride, seed);
                let (_, lut) = mk_lut(stride, seed ^ 1);
                let got: Vec<u32> = scan_topk(&lut, &idx, k)
                    .iter().map(|p| p.1).collect();
                let mut all: Vec<(f32, u32)> = (0..n)
                    .map(|i| (lut.score(idx.code(i)), i as u32))
                    .collect();
                all.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
                let want: Vec<u32> =
                    all[..k.min(n)].iter().map(|p| p.1).collect();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("scan {got:?} != naive {want:?}"))
                }
            },
        );
    }

    #[test]
    fn k_larger_than_n() {
        let idx = mk_index(5, 4, 7);
        let (_, lut) = mk_lut(4, 8);
        let got = scan_topk(&lut, &idx, 100);
        assert_eq!(got.len(), 5);
    }

    fn quantize(lut: &Lut, bits: u32) -> QuantizedLut {
        match bits {
            16 => QuantizedLut::u16_from(lut).expect("tables quantize"),
            8 => QuantizedLut::u8_from(lut).expect("tables quantize"),
            4 => QuantizedLut::u4_from(lut).expect("tables quantize"),
            _ => unreachable!(),
        }
    }

    /// 4-bit-friendly index: every code `< 16` (so the packed nibble
    /// mirror exists and `u4_from` LUTs apply).
    fn mk_index16(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> =
            (0..n * stride).map(|_| rng.below(16) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut16(stride: usize, seed: u64) -> Lut {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 16).map(|_| rng.next_f32() * 10.0).collect();
        Lut::Tables { m: stride, k: 16, tables, bias: 1.5 }
    }

    #[test]
    fn prop_packed_scan_equals_unpacked_scan_over_ragged_grid() {
        // pack → scan == flat (on-the-fly transpose) scan, bit-identical,
        // across ragged tails (n % 32 ≠ 0), n < 32, sub-ranges, and both
        // entry widths
        prop::forall_ok(
            4242,
            30,
            |r: &mut SplitMix64| {
                let n = match r.below(4) {
                    0 => 1 + r.below(31),            // n < BLOCK
                    1 => 32 * (1 + r.below(8)),      // exact blocks
                    _ => 1 + r.below(400),           // ragged
                };
                let stride = 1 + r.below(16);
                let k = 1 + r.below(25);
                let bits = if r.below(2) == 0 { 16u32 } else { 8 };
                // sub-range, occasionally empty (lo == hi)
                let lo = r.below(n + 1);
                let hi = lo + r.below(n + 1 - lo);
                (n, stride, k, bits, lo, hi, r.next_u64())
            },
            |&(n, stride, k, bits, lo, hi, seed)| {
                let flat = mk_index(n, stride, seed);
                let mut packed = mk_index(n, stride, seed);
                packed.ensure_packed();
                let (_, lut) = mk_lut(stride, seed ^ 3);
                let q = quantize(&lut, bits);
                let a = scan_range_topk_prec(&lut, Some(&q), &flat, lo, hi,
                                             k, None);
                let b = scan_range_topk_prec(&lut, Some(&q), &packed, lo,
                                             hi, k, None);
                if a == b {
                    Ok(())
                } else {
                    Err(format!("packed {b:?} != unpacked {a:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_int_scan_matches_f32_scan_outside_margin() {
        // the exact-rescore contract: whenever the f32 margin at the k-th
        // boundary exceeds twice the quantization error bound, the
        // integer kernels return exactly the f32 kernel's ids (and, being
        // exactly re-scored, the same scores)
        let mut gated = 0usize;
        let mut checked = 0usize;
        prop::forall_ok(
            1717,
            40,
            |r: &mut SplitMix64| {
                let n = 20 + r.below(300);
                let stride = 1 + r.below(16);
                let k = 1 + r.below(15);
                let bits = if r.below(2) == 0 { 16u32 } else { 8 };
                (n, stride, k, bits, r.next_u64())
            },
            |&(n, stride, k, bits, seed)| {
                let mut idx = mk_index(n, stride, seed);
                if seed % 2 == 0 {
                    idx.ensure_packed();
                }
                let (_, lut) = mk_lut(stride, seed ^ 5);
                let q = quantize(&lut, bits);
                // full f32 ranking, for the margin gate
                let mut all: Vec<(f32, u32)> = (0..n)
                    .map(|i| (lut.score(idx.code(i)), i as u32))
                    .collect();
                all.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
                checked += 1;
                if k >= n {
                    return Ok(());
                }
                let margin = all[k].0 - all[k - 1].0;
                // small multiplicative slack over the analytic bound
                // absorbs f32 accumulation fuzz at the gate boundary
                if margin <= 2.0 * q.max_score_error() * 1.001 + 1e-5 {
                    return Ok(()); // inside the quantization margin
                }
                gated += 1;
                let got = scan_range_topk_prec(&lut, Some(&q), &idx, 0, n,
                                               k, None);
                let want = &all[..k];
                if got.iter().map(|p| p.1).eq(want.iter().map(|p| p.1)) {
                    Ok(())
                } else {
                    Err(format!("bits={bits} got {got:?} want {want:?} \
                                 (margin {margin})"))
                }
            },
        );
        // u8 cases at wide strides legitimately fall inside the margin;
        // the u16 half of the grid must keep the property non-vacuous
        assert!(gated * 5 >= checked,
                "margin gate left the property vacuous: {gated}/{checked}");
    }

    #[test]
    fn prop_simd_scan_matches_scalar_oracle_over_ragged_grid() {
        // the tentpole contract: for every vectorized path (u16/u8
        // gather, u4 byte and nibble PSHUFB/TBL) the SIMD kernel's final
        // top-k equals the verbatim scalar oracle bit-for-bit, across
        // ragged tails, n < BLOCK, empty subranges, strides, k, widths,
        // and both packed/unpacked layouts.  On hosts without the
        // vector ISA both sides run scalar and the property is trivially
        // (but harmlessly) true — CI runs on AVX2-capable runners.
        prop::forall_ok(
            7777,
            60,
            |r: &mut SplitMix64| {
                let n = match r.below(4) {
                    0 => 1 + r.below(31),            // n < BLOCK
                    1 => 32 * (1 + r.below(8)),      // exact blocks
                    _ => 1 + r.below(400),           // ragged
                };
                let stride = 1 + r.below(16);
                let k = 1 + r.below(25);
                let bits = [16u32, 8, 4][r.below(3)];
                let packed = r.below(2) == 0;
                let lo = r.below(n + 1);
                let hi = lo + r.below(n + 1 - lo);
                (n, stride, k, bits, packed, lo, hi, r.next_u64())
            },
            |&(n, stride, k, bits, packed, lo, hi, seed)| {
                let (mut idx, lut) = if bits == 4 {
                    (mk_index16(n, stride, seed), mk_lut16(stride, seed ^ 9))
                } else {
                    let (idx, (_, lut)) =
                        (mk_index(n, stride, seed), mk_lut(stride, seed ^ 9));
                    (idx, lut)
                };
                if packed {
                    idx.ensure_packed();
                }
                let q = quantize(&lut, bits);
                let scalar = scan_range_topk_prec_forced(
                    &lut, Some(&q), &idx, lo, hi, k, None, true);
                let simd = scan_range_topk_prec_forced(
                    &lut, Some(&q), &idx, lo, hi, k, None, false);
                if scalar == simd {
                    Ok(())
                } else {
                    Err(format!("bits={bits} packed={packed} \
                                 simd {simd:?} != scalar {scalar:?}"))
                }
            },
        );
    }

    #[test]
    fn dispatch_entry_matches_both_forced_paths() {
        // whatever UNQ_FORCE_SCALAR / the CPU probe resolve to, the
        // undecorated entry must agree with BOTH pinned paths — i.e.
        // dispatch can never change a result (this is what makes the
        // env knob safe to flip in CI without a baseline change)
        let mut idx = mk_index16(300, 8, 51);
        idx.ensure_packed();
        let lut = mk_lut16(8, 52);
        for bits in [16u32, 8, 4] {
            let q = quantize(&lut, bits);
            let via_env = scan_range_topk_prec(&lut, Some(&q), &idx,
                                               0, 300, 12, None);
            for force in [true, false] {
                let pinned = scan_range_topk_prec_forced(
                    &lut, Some(&q), &idx, 0, 300, 12, None, force);
                assert_eq!(via_env, pinned, "bits={bits} force={force}");
            }
        }
    }

    #[test]
    fn u4_scan_prefers_nibble_mirror_and_matches_byte_path() {
        // packed (nibble mirror) vs unpacked (byte scratch) vs scalar:
        // all three u4 encodings of the same data must agree exactly
        let flat = mk_index16(200, 5, 61);
        let mut packed = mk_index16(200, 5, 61);
        packed.ensure_packed();
        assert!(packed.packed.as_ref().unwrap().nibbles.is_some(),
                "codes < 16 must carry the nibble mirror");
        let lut = mk_lut16(5, 62);
        let q = quantize(&lut, 4);
        let a = scan_range_topk_prec_forced(&lut, Some(&q), &packed,
                                            0, 200, 9, None, false);
        let b = scan_range_topk_prec_forced(&lut, Some(&q), &flat,
                                            0, 200, 9, None, false);
        let c = scan_range_topk_prec_forced(&lut, Some(&q), &packed,
                                            0, 200, 9, None, true);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn int_scan_exact_ties_keep_smallest_ids() {
        // duplicate rows: every copy scores identically in both domains;
        // the k smallest ids must win in scan output
        let stride = 6;
        let row: Vec<u8> = (0..stride as u8).collect();
        let codes: Vec<u8> = row.iter().copied().cycle().take(stride * 50)
            .collect();
        let idx = CompressedIndex::from_codes(50, stride, codes);
        let (_, lut) = mk_lut(stride, 11);
        for bits in [16u32, 8] {
            let q = quantize(&lut, bits);
            let got = scan_range_topk_prec(&lut, Some(&q), &idx, 0, 50, 7,
                                           None);
            let ids: Vec<u32> = got.iter().map(|p| p.1).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4, 5, 6], "bits={bits}");
        }
    }

    #[test]
    fn sharded_int_scan_merge_equals_full_scan_on_exact_tables() {
        // tables[c] = c quantizes *exactly* at both widths (u8: identity,
        // u16: ×257), so integer selection is lossless and the sharded
        // int scan must merge to exactly the full f32 scan — ragged
        // shard boundaries that straddle 32-row blocks included
        let mut rng = SplitMix64::new(33);
        let mut vals: Vec<u8> = (0..=255).collect();
        // deterministic shuffle so scores aren't in storage order
        for i in (1..vals.len()).rev() {
            vals.swap(i, rng.below(i + 1));
        }
        let idx = CompressedIndex::from_codes(256, 1, vals);
        let tables: Vec<f32> = (0..256).map(|c| c as f32).collect();
        let lut = Lut::Tables { m: 1, k: 256, tables, bias: 0.5 };
        let full_f32 = scan_topk(&lut, &idx, 25);
        for bits in [16u32, 8] {
            let q = quantize(&lut, bits);
            let parts = vec![
                scan_range_topk_prec(&lut, Some(&q), &idx, 0, 37, 25, None),
                scan_range_topk_prec(&lut, Some(&q), &idx, 37, 150, 25,
                                     None),
                scan_range_topk_prec(&lut, Some(&q), &idx, 150, 256, 25,
                                     None),
            ];
            let merged = merge_topk(parts, 25);
            assert_eq!(merged, full_f32, "bits={bits}");
        }
    }

    #[test]
    fn u4_scan_exact_ties_keep_smallest_ids() {
        // duplicate rows under a u4 LUT: the k smallest ids must win in
        // both the scalar oracle and the SIMD path
        let stride = 6;
        let row: Vec<u8> = (0..stride as u8).collect();
        let codes: Vec<u8> = row.iter().copied().cycle().take(stride * 50)
            .collect();
        let mut idx = CompressedIndex::from_codes(50, stride, codes);
        idx.ensure_packed();
        let lut = mk_lut16(stride, 13);
        let q = quantize(&lut, 4);
        for force in [true, false] {
            let got = scan_range_topk_prec_forced(&lut, Some(&q), &idx,
                                                  0, 50, 7, None, force);
            let ids: Vec<u32> = got.iter().map(|p| p.1).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4, 5, 6], "force={force}");
        }
    }

    #[test]
    fn sharded_u4_scan_merge_equals_full_scan_on_exact_tables() {
        // tables[c] = c·17 quantizes exactly at 8-bit entries, so u4
        // integer selection is lossless and the sharded u4 scan must
        // merge to exactly the full f32 scan (ragged shard boundaries
        // straddling 32-row blocks included)
        let mut rng = SplitMix64::new(37);
        let codes: Vec<u8> = (0..180).map(|_| rng.below(16) as u8).collect();
        let mut idx = CompressedIndex::from_codes(180, 1, codes);
        idx.ensure_packed();
        let tables: Vec<f32> = (0..16).map(|c| (c * 17) as f32).collect();
        let lut = Lut::Tables { m: 1, k: 16, tables, bias: 0.5 };
        let full_f32 = scan_topk(&lut, &idx, 20);
        let q = quantize(&lut, 4);
        for force in [true, false] {
            let parts = vec![
                scan_range_topk_prec_forced(&lut, Some(&q), &idx,
                                            0, 41, 20, None, force),
                scan_range_topk_prec_forced(&lut, Some(&q), &idx,
                                            41, 150, 20, None, force),
                scan_range_topk_prec_forced(&lut, Some(&q), &idx,
                                            150, 180, 20, None, force),
            ];
            let merged = merge_topk(parts, 20);
            assert_eq!(merged, full_f32, "force={force}");
        }
    }

    /// Rebuild an index from the admitted rows only, returning the
    /// compacted index plus the compact-row → original-id map — the
    /// honest oracle for in-selection filtering at every precision.
    fn admitted_subset(idx: &CompressedIndex, bm: &super::super::filter::FilterBitmap)
                       -> (CompressedIndex, Vec<u32>) {
        let stride = idx.stride;
        let mut codes = Vec::new();
        let mut to_orig = Vec::new();
        for i in 0..idx.n {
            if bm.is_admitted(i) {
                codes.extend_from_slice(idx.code(i));
                to_orig.push(i as u32);
            }
        }
        (CompressedIndex::from_codes(to_orig.len(), stride, codes), to_orig)
    }

    #[test]
    fn prop_filtered_scan_equals_admitted_subset_scan_at_all_precisions() {
        // the tentpole contract at the kernel level: a filtered scan is
        // exactly the scan of the admitted subset — at f32, u16, u8, u4,
        // SIMD and scalar, packed and unpacked, across selectivities
        // including 0 (empty, no panic) and 1 (bit-identical to plain)
        use crate::index::filter::{Filter, FilterBitmap};
        prop::forall_ok(
            6161,
            40,
            |r: &mut SplitMix64| {
                let n = 1 + r.below(300);
                let stride = 1 + r.below(12);
                let k = 1 + r.below(20);
                let bits = [0u32, 16, 8, 4][r.below(4)]; // 0 = f32
                let packed = r.below(2) == 0;
                let force = r.below(2) == 0;
                // selectivity grid: none / half-ish / all
                let modulus = [0usize, 2, 1][r.below(3)];
                (n, stride, k, bits, packed, force, modulus, r.next_u64())
            },
            |&(n, stride, k, bits, packed, force, modulus, seed)| {
                let (mut idx, lut) = if bits == 4 {
                    (mk_index16(n, stride, seed), mk_lut16(stride, seed ^ 7))
                } else {
                    let (idx, (_, lut)) =
                        (mk_index(n, stride, seed), mk_lut(stride, seed ^ 7));
                    (idx, lut)
                };
                // modulus 0 ⇒ admit nothing; else admit i % modulus == 0
                let tags: Vec<u64> = (0..n)
                    .map(|i| u64::from(modulus != 0 && i % modulus.max(1) == 0))
                    .collect();
                idx.set_tags(tags);
                if packed {
                    idx.ensure_packed();
                }
                let q = (bits != 0).then(|| quantize(&lut, bits));
                let bm = FilterBitmap::build(&Filter::TagEq(1), &idx);
                let got = scan_range_topk_prec_forced(
                    &lut, q.as_ref(), &idx, 0, n, k, Some(&bm), force);
                let (sub, to_orig) = admitted_subset(&idx, &bm);
                if sub.n == 0 {
                    return if got.is_empty() {
                        Ok(())
                    } else {
                        Err(format!("selectivity 0 returned {got:?}"))
                    };
                }
                let mut sub2 = sub;
                if packed {
                    sub2.ensure_packed();
                }
                let want: Vec<(f32, u32)> = scan_range_topk_prec_forced(
                    &lut, q.as_ref(), &sub2, 0, sub2.n, k, None, force)
                    .into_iter()
                    .map(|(s, id)| (s, to_orig[id as usize]))
                    .collect();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("bits={bits} modulus={modulus} \
                                 filtered {got:?} != subset {want:?}"))
                }
            },
        );
    }

    #[test]
    fn full_selectivity_filter_is_bit_identical_to_plain_scan() {
        use crate::index::filter::{Filter, FilterBitmap};
        let mut idx = mk_index(260, 6, 91);
        idx.set_tags(vec![3; 260]);
        idx.ensure_packed();
        let (_, lut) = mk_lut(6, 92);
        let bm = FilterBitmap::build(&Filter::TagEq(3), &idx);
        for bits in [0u32, 16, 8] {
            let q = (bits != 0).then(|| quantize(&lut, bits));
            let plain = scan_range_topk_prec(&lut, q.as_ref(), &idx,
                                             0, 260, 11, None);
            let filtered = scan_range_topk_prec(&lut, q.as_ref(), &idx,
                                                0, 260, 11, Some(&bm));
            assert_eq!(plain, filtered, "bits={bits}");
        }
    }

    #[test]
    fn filtered_prefiltered_scan_scores_only_admitted_survivors() {
        use crate::index::filter::{Filter, FilterBitmap};
        // full keep: the prefiltered path must reduce to the filtered
        // plain scan exactly
        let mut idx = mk_index(300, 7, 73);
        idx.set_tags((0..300).map(|i| (i % 2) as u64).collect());
        let (_, lut) = mk_lut(7, 74);
        let sketches = vec![0u64; 300];
        let bm = FilterBitmap::build(&Filter::TagEq(0), &idx);
        let want = scan_range_topk(&lut, &idx, 10, 280, 9, Some(&bm));
        let got = scan_range_topk_prefiltered(&lut, &idx, &sketches, 0,
                                              10, 280, 9, 9999, Some(&bm));
        assert_eq!(got, want);
        for (_, id) in got {
            assert_eq!(id % 2, 0, "non-admitted row leaked through");
        }
    }

    #[test]
    fn prefilter_survivors_threshold_semantics() {
        // sketches at Hamming distances 0, 1, 1, 2, 3 from the query:
        // keep = 2 admits the distance-1 tie (3 survivors — over-admit,
        // never under-admit), keep = 4 reaches distance 2
        let sk = [0u64, 1, 2, 3, 7];
        let got = prefilter_survivors(&sk, 0, 0, 5, 2);
        assert_eq!(got, vec![0, 1, 2]);
        let got = prefilter_survivors(&sk, 0, 0, 5, 4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        // keep beyond the range admits everything
        let got = prefilter_survivors(&sk, 0, 0, 5, 99);
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        // subrange offsets are preserved in the returned ids
        let got = prefilter_survivors(&sk, 0, 2, 5, 1);
        assert_eq!(got, vec![2]);
    }

    #[test]
    fn prefiltered_scan_with_full_keep_is_bit_identical() {
        // keep ≥ range: the pre-filter must get out of the way entirely
        let idx = mk_index(300, 7, 71);
        let (_, lut) = mk_lut(7, 72);
        let sketches = vec![0u64; 300]; // content irrelevant at full keep
        let want = scan_range_topk(&lut, &idx, 20, 260, 10, None);
        let got = scan_range_topk_prefiltered(&lut, &idx, &sketches, 0,
                                              20, 260, 10, 9999, None);
        assert_eq!(got, want);
    }

    #[test]
    fn prefiltered_scan_with_informative_sketches_recovers_f32_topk() {
        // deterministic recall-safety: give row of f32-rank r a sketch
        // with ⌊r·64/n⌋ set bits (qsketch = 0), so sketch distance
        // orders exactly like the f32 score — the pruned scan must then
        // return the f32 top-k bit-identically while genuinely pruning
        // (non-vacuity: keep < range)
        let n = 320;
        let idx = mk_index(n, 6, 81);
        let (_, lut) = mk_lut(6, 82);
        let mut ranked: Vec<(f32, u32)> = (0..n)
            .map(|i| (lut.score(idx.code(i)), i as u32))
            .collect();
        ranked.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        let mut sketches = vec![0u64; n];
        for (rank, &(_, id)) in ranked.iter().enumerate() {
            let bits = rank * 64 / n;
            sketches[id as usize] = (1u64 << bits).wrapping_sub(1);
        }
        let k = 10;
        let margin = 4;
        assert!(k * margin < n, "prune must actually engage");
        let want = scan_range_topk(&lut, &idx, 0, n, k, None);
        let got = scan_range_topk_prefiltered(&lut, &idx, &sketches, 0,
                                              0, n, k, margin, None);
        assert_eq!(got, want);
    }
}
