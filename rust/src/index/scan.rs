//! The ADC scan hot path.
//!
//! `scan_lut_topk` is the specialized LUT loop (the overwhelmingly common
//! case: PQ/OPQ/RVQ/LSQ/UNQ all scan through `Lut::Tables`); `scan_topk`
//! dispatches, falling back to the generic `Lut::score` for the lattice's
//! direct dot scoring.
//!
//! Performance notes (see `rust/DESIGN.md` §2 for measurements):
//! * the per-row loop over `stride` table lookups is unrolled by the
//!   compiler for the fixed strides we exercise; the LUT layout is
//!   position-major (`tables[j·K + code[j]]`, the contract documented on
//!   [`Lut::Tables`]) so all lookups hit one small table
//!   (8–17 rows × 256 × 4 B ≤ 17 KB, L1-resident);
//! * the bounded heap makes the common case (candidate worse than the
//!   current k-th best) a single compare-and-skip;
//! * scores accumulate in plain f32 — identical to the paper's setup.

use crate::linalg::TopK;
use crate::quant::Lut;

use super::CompressedIndex;

/// Scan the whole index with a table LUT, returning the k smallest
/// `(score, id)` pairs sorted ascending.
pub fn scan_lut_topk(tables: &[f32], k_width: usize, bias: f32,
                     index: &CompressedIndex, lo: usize, hi: usize,
                     k: usize) -> Vec<(f32, u32)> {
    let stride = index.stride;
    let mut top = TopK::new(k);
    let mut worst = f32::INFINITY;
    let codes = &index.codes[lo * stride..hi * stride];
    // 4-row software pipeline: the per-row table gathers are independent,
    // so interleaving four rows gives the core 4× the memory-level
    // parallelism on the (L2-missing) code stream — see rust/DESIGN.md §2
    // for the measured effect at n = 1M.
    let n_rows = hi - lo;
    let quads = n_rows / 4;
    for qi in 0..quads {
        let base0 = qi * 4 * stride;
        let (mut a0, mut a1, mut a2, mut a3) = (bias, bias, bias, bias);
        for j in 0..stride {
            // safety: tables is (stride, k_width); code bytes < k_width by
            // construction (encoders emit ids < K)
            unsafe {
                let t = tables.as_ptr().add(j * k_width);
                a0 += *t.add(*codes.get_unchecked(base0 + j) as usize);
                a1 += *t.add(*codes.get_unchecked(base0 + stride + j) as usize);
                a2 += *t.add(*codes.get_unchecked(base0 + 2 * stride + j) as usize);
                a3 += *t.add(*codes.get_unchecked(base0 + 3 * stride + j) as usize);
            }
        }
        let row = lo + qi * 4;
        if a0 < worst {
            top.push(a0, row as u32);
            worst = top.worst();
        }
        if a1 < worst {
            top.push(a1, (row + 1) as u32);
            worst = top.worst();
        }
        if a2 < worst {
            top.push(a2, (row + 2) as u32);
            worst = top.worst();
        }
        if a3 < worst {
            top.push(a3, (row + 3) as u32);
            worst = top.worst();
        }
    }
    for row in quads * 4..n_rows {
        let code = &codes[row * stride..(row + 1) * stride];
        let mut acc = bias;
        for (j, &c) in code.iter().enumerate() {
            acc += unsafe { *tables.get_unchecked(j * k_width + c as usize) };
        }
        if acc < worst {
            top.push(acc, (lo + row) as u32);
            worst = top.worst();
        }
    }
    top.into_sorted()
}

/// Generic scan via `Lut::score` (used by the lattice direct path).
pub fn scan_generic_topk(lut: &Lut, index: &CompressedIndex, lo: usize,
                         hi: usize, k: usize) -> Vec<(f32, u32)> {
    let mut top = TopK::new(k);
    let mut worst = f32::INFINITY;
    for i in lo..hi {
        let s = lut.score(index.code(i));
        if s < worst {
            top.push(s, i as u32);
            worst = top.worst();
        }
    }
    top.into_sorted()
}

/// Dispatching scan over the full index.
pub fn scan_topk(lut: &Lut, index: &CompressedIndex, k: usize)
                 -> Vec<(f32, u32)> {
    scan_range_topk(lut, index, 0, index.n, k)
}

/// Dispatching scan over `[lo, hi)` — the shard work unit the batch
/// executor (`exec::plan`) fans out as one task per `(query, shard)`.
pub fn scan_range_topk(lut: &Lut, index: &CompressedIndex, lo: usize,
                       hi: usize, k: usize) -> Vec<(f32, u32)> {
    let hi = hi.min(index.n);
    match lut {
        Lut::Tables { m, k: kw, tables, bias } => {
            debug_assert_eq!(*m, index.stride,
                             "LUT rows must match index stride");
            scan_lut_topk(tables, *kw, *bias, index, lo, hi, k)
        }
        Lut::Direct { .. } => scan_generic_topk(lut, index, lo, hi, k),
    }
}

/// Merge several per-shard top-k lists into a global top-k.
pub fn merge_topk(mut parts: Vec<Vec<(f32, u32)>>, k: usize) -> Vec<(f32, u32)> {
    let mut top = TopK::new(k);
    for part in parts.drain(..) {
        for (s, id) in part {
            top.push(s, id);
        }
    }
    top.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::SplitMix64};

    fn mk_index(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> = (0..n * stride).map(|_| rng.below(256) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut(stride: usize, seed: u64) -> (Vec<f32>, Lut) {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 256).map(|_| rng.next_f32() * 10.0).collect();
        let lut = Lut::Tables { m: stride, k: 256, tables: tables.clone(),
                                bias: 1.5 };
        (tables, lut)
    }

    #[test]
    fn scan_matches_naive_argsort() {
        let idx = mk_index(500, 8, 1);
        let (_, lut) = mk_lut(8, 2);
        let got = scan_topk(&lut, &idx, 10);
        // naive
        let mut all: Vec<(f32, u32)> = (0..500)
            .map(|i| (lut.score(idx.code(i)), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<u32> = all[..10].iter().map(|p| p.1).collect();
        let got_ids: Vec<u32> = got.iter().map(|p| p.1).collect();
        assert_eq!(got_ids, want);
    }

    #[test]
    fn sharded_scan_merge_equals_full_scan() {
        let idx = mk_index(1000, 9, 3);
        let (_, lut) = mk_lut(9, 4);
        let full = scan_topk(&lut, &idx, 25);
        let parts = vec![
            scan_range_topk(&lut, &idx, 0, 400, 25),
            scan_range_topk(&lut, &idx, 400, 700, 25),
            scan_range_topk(&lut, &idx, 700, 1000, 25),
        ];
        let merged = merge_topk(parts, 25);
        assert_eq!(full.iter().map(|p| p.1).collect::<Vec<_>>(),
                   merged.iter().map(|p| p.1).collect::<Vec<_>>());
    }

    #[test]
    fn prop_scan_is_exact_selection() {
        // property over random tables/codes/sizes: scan == argsort prefix
        prop::forall_ok(
            99,
            25,
            |r: &mut SplitMix64| {
                let n = 20 + r.below(300);
                let stride = 1 + r.below(16);
                let k = 1 + r.below(20);
                (n, stride, k, r.next_u64())
            },
            |&(n, stride, k, seed)| {
                let idx = mk_index(n, stride, seed);
                let (_, lut) = mk_lut(stride, seed ^ 1);
                let got: Vec<u32> = scan_topk(&lut, &idx, k)
                    .iter().map(|p| p.1).collect();
                let mut all: Vec<(f32, u32)> = (0..n)
                    .map(|i| (lut.score(idx.code(i)), i as u32))
                    .collect();
                all.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
                let want: Vec<u32> =
                    all[..k.min(n)].iter().map(|p| p.1).collect();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("scan {got:?} != naive {want:?}"))
                }
            },
        );
    }

    #[test]
    fn k_larger_than_n() {
        let idx = mk_index(5, 4, 7);
        let (_, lut) = mk_lut(4, 8);
        let got = scan_topk(&lut, &idx, 100);
        assert_eq!(got.len(), 5);
    }
}
