//! Runtime-dispatched SIMD accumulators for the blocked fast-scan
//! kernels (rust/DESIGN.md §9).
//!
//! The scalar kernels in [`super::scan`] stay the semantic oracle; this
//! module only replaces the *inner accumulation loop* over one 32-row
//! block with vector code, selected once per process:
//!
//! * x86_64 + AVX2 — u8/u16 table rows are widened to u32 once per scan
//!   call and gathered with `VPGATHERDD`; 4-bit rows (16 × u8, one
//!   `__m128i`) are gathered in-register with `PSHUFB`.
//! * aarch64 — NEON is mandatory, so the 4-bit `TBL` kernel is always
//!   available; u8/u16 stay scalar (NEON has no gather instruction, and
//!   the scalar 32-lane loop already autovectorizes respectably).
//! * anything else, or `UNQ_FORCE_SCALAR=1` — scalar fallback.
//!
//! Every wrapper here is safe: the feature probe is checked before any
//! `#[target_feature]` function is entered, and slice geometry is
//! asserted at the boundary.  Accumulation is bit-identical to the
//! scalar kernels by construction (integer adds reassociate freely),
//! which the scan property tests pin down.

// Inner unsafe blocks stay mandatory (and SAFETY-commented) even inside
// the `unsafe fn` kernels below.
#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::packed::BLOCK;

/// `UNQ_FORCE_SCALAR` override state: 0 = follow the environment,
/// 1 = force scalar, 2 = force dispatch (bench baseline toggling).
static FORCE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// The environment probe, read once (scans are hot; re-reading the
/// environment per block would dwarf the kernel).
static ENV_FORCE: OnceLock<bool> = OnceLock::new();

/// True when the scalar fallback is pinned — by `UNQ_FORCE_SCALAR`
/// (`1`/`true`/`yes`) or by [`set_force_scalar_for_bench`].
pub fn scalar_forced() -> bool {
    match FORCE_OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => *ENV_FORCE.get_or_init(|| {
            matches!(std::env::var("UNQ_FORCE_SCALAR").ok().as_deref(),
                     Some("1") | Some("true") | Some("yes"))
        }),
    }
}

/// Process-wide dispatch override for the bench binaries, which time
/// scalar and SIMD variants in one process.  Tests must NOT use this
/// (the test harness is parallel); they pass explicit `force_scalar`
/// arguments to the `_forced` scan entries instead.
pub fn set_force_scalar_for_bench(force: bool) {
    FORCE_OVERRIDE.store(if force { 1 } else { 2 }, Ordering::Relaxed);
}

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// Whether the widened-gather integer kernel (u8/u16 entries) runs in
/// vector code under current dispatch.
pub fn int_kernel_active() -> bool {
    if scalar_forced() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        have_avx2()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Whether the 4-bit in-register LUT kernel runs in vector code under
/// current dispatch.
pub fn u4_kernel_active() -> bool {
    if scalar_forced() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        have_avx2()
    }
    #[cfg(target_arch = "aarch64")]
    {
        true // NEON is architecturally mandatory on aarch64
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Human-readable name of the active instruction set (bench/CLI
/// reporting).
pub fn active_name() -> &'static str {
    if scalar_forced() {
        return "scalar (forced)";
    }
    #[cfg(target_arch = "x86_64")]
    {
        if have_avx2() { "avx2" } else { "scalar" }
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "scalar"
    }
}

/// Accumulate one block with u32-widened tables via hardware gather.
/// Caller must have checked [`int_kernel_active`]; every code byte in
/// `blk` must be `< kw` (the packed-layout contract — pad lanes are 0).
pub fn accumulate_widened(widened: &[u32], kw: usize, stride: usize,
                          blk: &[u8], acc: &mut [u32; BLOCK]) {
    assert_eq!(widened.len(), stride * kw);
    assert_eq!(blk.len(), stride * BLOCK);
    #[cfg(target_arch = "x86_64")]
    // SAFETY: int_kernel_active() gates entry on the runtime AVX2 probe;
    // slice geometry is asserted above, and code bytes index within each
    // kw-wide table row by the packed-layout contract.
    unsafe {
        avx2::accumulate_widened(widened, kw, stride, blk, acc)
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (widened, kw, stride, blk, acc);
        unreachable!("no widened-gather kernel on this architecture");
    }
}

/// Accumulate one block of byte-per-code 4-bit data (each code `< 16`)
/// against 16-wide u8 table rows.  Caller must have checked
/// [`u4_kernel_active`]; `stride ≤ 256` (the `u4_from` bound) keeps the
/// internal 16-bit lanes from overflowing (`256 · 255 < 2¹⁶`).
pub fn accumulate_u4_bytes(tables: &[u8], stride: usize, blk: &[u8],
                           acc: &mut [u32; BLOCK]) {
    assert_eq!(tables.len(), stride * crate::quant::U4_ROW);
    assert_eq!(blk.len(), stride * BLOCK);
    assert!(stride <= 256, "u4 rows are bounded by the u4_from ceiling");
    #[cfg(target_arch = "x86_64")]
    // SAFETY: u4_kernel_active() gates entry on the runtime AVX2 probe;
    // slice geometry is asserted above and codes are < 16 by contract.
    unsafe {
        avx2::accumulate_u4(tables, stride, avx2::U4Source::Bytes(blk), acc)
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON is architecturally mandatory on aarch64; slice
    // geometry is asserted above.
    unsafe {
        neon::accumulate_u4(tables, stride, neon::U4Source::Bytes(blk), acc)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (tables, stride, blk, acc);
        unreachable!("no u4 kernel on this architecture");
    }
}

/// Accumulate one block from the packed nibble mirror (16 bytes per
/// position: lane `i` low nibble, lane `i + 16` high nibble).  Same
/// contract as [`accumulate_u4_bytes`].
pub fn accumulate_u4_nibbles(tables: &[u8], stride: usize, nib: &[u8],
                             acc: &mut [u32; BLOCK]) {
    assert_eq!(tables.len(), stride * crate::quant::U4_ROW);
    assert_eq!(nib.len(), stride * (BLOCK / 2));
    assert!(stride <= 256, "u4 rows are bounded by the u4_from ceiling");
    #[cfg(target_arch = "x86_64")]
    // SAFETY: u4_kernel_active() gates entry on the runtime AVX2 probe;
    // slice geometry is asserted above and nibbles are < 16 by layout.
    unsafe {
        avx2::accumulate_u4(tables, stride, avx2::U4Source::Nibbles(nib),
                            acc)
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON is architecturally mandatory on aarch64; slice
    // geometry is asserted above.
    unsafe {
        neon::accumulate_u4(tables, stride, neon::U4Source::Nibbles(nib),
                            acc)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (tables, stride, nib, acc);
        unreachable!("no u4 kernel on this architecture");
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::BLOCK;
    use std::arch::x86_64::*;

    /// One table row per step, 32 lanes as 4 × 8 u32 gathers held in
    /// registers across the whole position loop.
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate_widened(widened: &[u32], kw: usize,
                                     stride: usize, blk: &[u8],
                                     acc: &mut [u32; BLOCK]) {
        // SAFETY: (whole body) caller asserts `widened` is stride × kw
        // and `blk` is stride × 32; code bytes are < kw so every gather
        // offset lands inside its table row; loads/stores are unaligned
        // intrinsics, so no alignment requirement.
        unsafe {
            let mut a0 = _mm256_setzero_si256();
            let mut a1 = _mm256_setzero_si256();
            let mut a2 = _mm256_setzero_si256();
            let mut a3 = _mm256_setzero_si256();
            for j in 0..stride {
                let t = widened.as_ptr().add(j * kw) as *const i32;
                let lane = blk.as_ptr().add(j * BLOCK);
                let i0 = _mm256_cvtepu8_epi32(
                    _mm_loadl_epi64(lane as *const __m128i));
                let i1 = _mm256_cvtepu8_epi32(
                    _mm_loadl_epi64(lane.add(8) as *const __m128i));
                let i2 = _mm256_cvtepu8_epi32(
                    _mm_loadl_epi64(lane.add(16) as *const __m128i));
                let i3 = _mm256_cvtepu8_epi32(
                    _mm_loadl_epi64(lane.add(24) as *const __m128i));
                a0 = _mm256_add_epi32(a0, _mm256_i32gather_epi32::<4>(t, i0));
                a1 = _mm256_add_epi32(a1, _mm256_i32gather_epi32::<4>(t, i1));
                a2 = _mm256_add_epi32(a2, _mm256_i32gather_epi32::<4>(t, i2));
                a3 = _mm256_add_epi32(a3, _mm256_i32gather_epi32::<4>(t, i3));
            }
            let p = acc.as_mut_ptr();
            _mm256_storeu_si256(p as *mut __m256i, a0);
            _mm256_storeu_si256(p.add(8) as *mut __m256i, a1);
            _mm256_storeu_si256(p.add(16) as *mut __m256i, a2);
            _mm256_storeu_si256(p.add(24) as *mut __m256i, a3);
        }
    }

    /// Where one position's 32 code nibbles come from: a 32-byte
    /// position row (one code per byte) or its 16-byte nibble mirror.
    /// A plain enum rather than a generic closure keeps the kernel
    /// non-generic (a `#[target_feature]` requirement on older rustc).
    #[derive(Clone, Copy)]
    pub enum U4Source<'a> {
        Bytes(&'a [u8]),
        Nibbles(&'a [u8]),
    }

    /// Gather 32 u8 entries from one 16-entry row with PSHUFB (the row
    /// broadcast to both 128-bit lanes), accumulating in u16 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate_u4(tables: &[u8], stride: usize,
                                src: U4Source<'_>,
                                acc: &mut [u32; BLOCK]) {
        // SAFETY: (whole body) caller asserts `tables` is stride × 16
        // rows and the source slab is stride × 32 (bytes) or stride × 16
        // (nibbles), so every load is in bounds; codes are < 16 by
        // contract, so PSHUFB (which indexes each 128-bit lane by the
        // low nibble and zeroes on a set high bit) selects real entries;
        // stride ≤ 256 bounds every u16 lane by 256 · 255 < 2¹⁶ — no
        // wrap.  Nibble decode: low nibbles are lanes 0..16 and high
        // nibbles lanes 16..32 by the mirror layout (the 16-bit shift
        // bleeds bits across byte pairs, masked off by 0x0F).
        unsafe {
            let mask = _mm_set1_epi8(0x0F);
            let mut a0 = _mm256_setzero_si256(); // rows 0..16, u16 lanes
            let mut a1 = _mm256_setzero_si256(); // rows 16..32
            for j in 0..stride {
                let codes = match src {
                    U4Source::Bytes(blk) => _mm256_loadu_si256(
                        blk.as_ptr().add(j * BLOCK) as *const __m256i),
                    U4Source::Nibbles(nib) => {
                        let packed = _mm_loadu_si128(
                            nib.as_ptr().add(j * (BLOCK / 2))
                                as *const __m128i);
                        let lo = _mm_and_si128(packed, mask);
                        let hi = _mm_and_si128(_mm_srli_epi16::<4>(packed),
                                               mask);
                        _mm256_set_m128i(hi, lo)
                    }
                };
                let row = _mm_loadu_si128(
                    tables.as_ptr().add(j * 16) as *const __m128i);
                let row2 = _mm256_broadcastsi128_si256(row);
                let vals = _mm256_shuffle_epi8(row2, codes);
                a0 = _mm256_add_epi16(a0, _mm256_cvtepu8_epi16(
                    _mm256_castsi256_si128(vals)));
                a1 = _mm256_add_epi16(a1, _mm256_cvtepu8_epi16(
                    _mm256_extracti128_si256::<1>(vals)));
            }
            let p = acc.as_mut_ptr();
            for (i, a) in [a0, a1].into_iter().enumerate() {
                let lo = _mm256_cvtepu16_epi32(_mm256_castsi256_si128(a));
                let hi = _mm256_cvtepu16_epi32(
                    _mm256_extracti128_si256::<1>(a));
                _mm256_storeu_si256(p.add(i * 16) as *mut __m256i, lo);
                _mm256_storeu_si256(p.add(i * 16 + 8) as *mut __m256i, hi);
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::BLOCK;
    use std::arch::aarch64::*;

    /// Byte-row vs nibble-mirror source, mirroring the AVX2 enum.
    #[derive(Clone, Copy)]
    pub enum U4Source<'a> {
        Bytes(&'a [u8]),
        Nibbles(&'a [u8]),
    }

    /// TBL-gather 32 u8 entries per position from one 16-entry row,
    /// accumulating in u16 lanes (stride ≤ 256 keeps them exact).
    #[target_feature(enable = "neon")]
    pub unsafe fn accumulate_u4(tables: &[u8], stride: usize,
                                src: U4Source<'_>,
                                acc: &mut [u32; BLOCK]) {
        // SAFETY: (whole body) caller asserts `tables` is stride × 16
        // rows and the source slab is stride × 32 (bytes) or stride × 16
        // (nibbles), so every load is in bounds; codes are < 16 by
        // contract, so TBL (which zeroes out-of-range indices) selects
        // real entries; stride ≤ 256 bounds every u16 lane by
        // 256 · 255 < 2¹⁶.  Nibble decode: low nibbles are lanes 0..16
        // and high nibbles lanes 16..32 by the mirror layout.
        unsafe {
            let mut a0 = vdupq_n_u16(0); // rows 0..8
            let mut a1 = vdupq_n_u16(0); // rows 8..16
            let mut a2 = vdupq_n_u16(0); // rows 16..24
            let mut a3 = vdupq_n_u16(0); // rows 24..32
            for j in 0..stride {
                let (c0, c1) = match src {
                    U4Source::Bytes(blk) => {
                        (vld1q_u8(blk.as_ptr().add(j * BLOCK)),
                         vld1q_u8(blk.as_ptr().add(j * BLOCK + 16)))
                    }
                    U4Source::Nibbles(nib) => {
                        let packed =
                            vld1q_u8(nib.as_ptr().add(j * (BLOCK / 2)));
                        (vandq_u8(packed, vdupq_n_u8(0x0F)),
                         vshrq_n_u8::<4>(packed))
                    }
                };
                let row = vld1q_u8(tables.as_ptr().add(j * 16));
                let v0 = vqtbl1q_u8(row, c0);
                let v1 = vqtbl1q_u8(row, c1);
                a0 = vaddw_u8(a0, vget_low_u8(v0));
                a1 = vaddw_u8(a1, vget_high_u8(v0));
                a2 = vaddw_u8(a2, vget_low_u8(v1));
                a3 = vaddw_u8(a3, vget_high_u8(v1));
            }
            let p = acc.as_mut_ptr();
            for (i, a) in [a0, a1, a2, a3].into_iter().enumerate() {
                vst1q_u32(p.add(i * 8), vmovl_u16(vget_low_u16(a)));
                vst1q_u32(p.add(i * 8 + 4), vmovl_u16(vget_high_u16(a)));
            }
        }
    }
}
