//! `unq tables` CLI wrapper around [`unq::eval::tables`].

use unq::eval::tables::run_tables;
use unq::Result;

use super::{base_config, Flags};

pub fn cmd_tables(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    run_tables(&cfg, f.get("table").unwrap_or("all"))
}
