//! The batch-first query execution engine.
//!
//! Everything between "a batch of LUTs" and "per-query neighbor lists"
//! lives here, shared by the offline [`crate::index::SearchEngine`] and
//! the serving [`crate::coordinator`]:
//!
//! * [`pool`] — persistent named worker threads over a bounded job queue,
//!   with scoped (borrowing) batch submission and graceful shutdown;
//! * [`plan`] — the generic [`plan::ScanTask`] fan-out (slot-merged,
//!   submission-ordered `merge_topk` reduction), the flat
//!   `QueryBatch × IndexShard` plan built on it, and the batched
//!   gather → `reconstruct_batch` rerank.  The IVF subsystem
//!   ([`crate::ivf`]) plans per-(query, probed-list) tasks through the
//!   same executor so mixed-list batches fill the pool.
//!
//! The execution contract is strict determinism: for any
//! `(num_threads, shard_rows)` the results are bit-identical to the
//! single-threaded, single-shard scan — parallelism changes wall-clock,
//! never answers.  `rust/DESIGN.md` §2 records the scan-path performance
//! notes behind the sharding defaults.

pub mod plan;
pub mod pool;

pub use plan::{rerank_batch, shard_ranges, shard_ranges_in, Executor,
               ScanTask};
pub use pool::WorkerPool;
