//! The batch-first query execution engine.
//!
//! Everything between "a batch of LUTs" and "per-query neighbor lists"
//! lives here, shared by the offline [`crate::index::SearchEngine`] and
//! the serving [`crate::coordinator`]:
//!
//! * [`pool`] — persistent named worker threads over a bounded job queue,
//!   with scoped (borrowing) batch submission and graceful shutdown;
//! * [`plan`] — the generic [`plan::ScanTask`] fan-out (slot-merged,
//!   submission-ordered `merge_topk` reduction), the flat
//!   `QueryBatch × IndexShard` plan built on it, and the batched
//!   gather → `reconstruct_batch` rerank.  The IVF subsystem
//!   ([`crate::ivf`]) plans per-(query, probed-list) tasks through the
//!   same executor so mixed-list batches fill the pool.
//!
//! Both entrypoints ([`Executor::scan_batch`] and
//! [`Executor::run_scan_tasks`]) take a per-plan [`plan::ScanSpec`]
//! carrying every scan axis — kernel precision, the 1-bit pre-filter,
//! the metadata predicate filter — so new axes become fields, not new
//! entrypoint suffixes.
//!
//! The execution contract is strict determinism: at the default
//! `ScanPrecision::F32`, for any `(num_threads, shard_rows)` the results
//! are bit-identical to the single-threaded, single-shard scan —
//! parallelism changes wall-clock, never answers.  The integer scan
//! precisions (`U16`/`U8`, selected per plan via
//! [`plan::ScanSpec::precision`]) are
//! deterministic **per shard decomposition**: results are identical
//! across executors for a fixed `shard_rows`, but per-shard integer
//! selection can swap candidates inside the LUT quantization margin
//! when the decomposition itself changes — which includes the `0 = auto`
//! setting, whose shard size derives from the pool size.  Pin an
//! explicit `shard_rows` when integer-precision results must reproduce
//! across different pool sizes (`rust/DESIGN.md` §6).  §2 records the
//! scan-path performance notes behind the sharding defaults.

pub mod plan;
pub mod pool;

pub use plan::{rerank_batch, shard_ranges, shard_ranges_in, Executor,
               PrefilterPlan, ScanSpec, ScanTask};
pub use pool::WorkerPool;
