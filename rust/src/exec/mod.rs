//! The batch-first query execution engine.
//!
//! Everything between "a batch of LUTs" and "per-query neighbor lists"
//! lives here, shared by the offline [`crate::index::SearchEngine`] and
//! the serving [`crate::coordinator`]:
//!
//! * [`pool`] — persistent named worker threads over a bounded job queue,
//!   with scoped (borrowing) batch submission and graceful shutdown;
//! * [`plan`] — the `QueryBatch × IndexShard` scan plan (one task per
//!   (query, shard) pair, [`plan::shard_ranges`] partitioning,
//!   shard-ordered `merge_topk` reduction) and the batched
//!   gather → `reconstruct_batch` rerank.
//!
//! The execution contract is strict determinism: for any
//! `(num_threads, shard_rows)` the results are bit-identical to the
//! single-threaded, single-shard scan — parallelism changes wall-clock,
//! never answers.  `rust/DESIGN.md` §2 records the scan-path performance
//! notes behind the sharding defaults.

pub mod plan;
pub mod pool;

pub use plan::{rerank_batch, shard_ranges, Executor};
pub use pool::WorkerPool;
