//! Persistent worker pool: the thread substrate of the batch executor.
//!
//! A fixed set of named threads (`unq-exec-0..`) pulls jobs from one
//! bounded queue (crossbeam is unavailable offline, so the queue is a
//! `std::sync::mpsc::sync_channel` behind a mutex-shared receiver — on the
//! coarse-grained tasks the planner emits, queue contention is
//! unmeasurable).  Two submission modes:
//!
//! * [`WorkerPool::spawn`] — fire-and-forget `'static` jobs;
//! * [`WorkerPool::run_scoped`] — a batch of *borrowing* tasks run to
//!   completion before the call returns, which is what lets scan tasks
//!   borrow the index and LUTs directly instead of cloning them behind
//!   `Arc`s.
//!
//! Shutdown is graceful: dropping the pool closes the queue, every worker
//! drains its backlog and exits, and `Drop` joins them all.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs;

/// A unit of work executed on a pool thread.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Jobs of queue slack per worker: enough to keep every thread busy while
/// the submitter is still enqueueing, small enough to bound memory when a
/// producer runs far ahead (backpressure via the bounded channel).
const QUEUE_SLACK_PER_WORKER: usize = 4;

/// Fixed-size pool of persistent, named worker threads.
pub struct WorkerPool {
    /// `None` only during `Drop`, which closes the queue before joining.
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `num_threads` workers (clamped to at least 1).
    pub fn new(num_threads: usize) -> WorkerPool {
        let n = num_threads.max(1);
        let (tx, rx) = mpsc::sync_channel::<Job>(n * QUEUE_SLACK_PER_WORKER);
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("unq-exec-{i}"))
                    .spawn(move || worker_main(rx))
                    .expect("spawn exec worker"),
            );
        }
        WorkerPool { tx: Some(tx), workers }
    }

    pub fn num_threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit one `'static` job; blocks when the bounded queue is full.
    pub fn spawn(&self, job: Job) {
        // queue depth = jobs submitted but not yet picked up by a worker
        obs::global().exec_queue_depth.inc();
        self.tx
            .as_ref()
            .expect("pool is shut down")
            .send(job)
            .expect("exec workers exited");
    }

    /// Run a batch of tasks that may borrow from the caller's stack, and
    /// block until every one of them has finished executing.
    ///
    /// Panics if any task panicked on a worker (the worker itself
    /// survives; see [`worker_main`]).
    pub fn run_scoped<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = tasks.len();
        let (done_tx, done_rx) = mpsc::sync_channel::<()>(n.max(1));
        for task in tasks {
            let done = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                task();
                let _ = done.send(());
            });
            // SAFETY: the job runs strictly before this function returns —
            // the receive loop below blocks until every job either sent a
            // completion token or was dropped by its worker (each job owns
            // a `done_tx` clone, so the channel only disconnects once all
            // jobs are consumed) — so the 'env borrows outlive every use.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            self.spawn(job);
        }
        drop(done_tx);
        for _ in 0..n {
            if done_rx.recv().is_err() {
                // disconnection before n tokens: some task was dropped
                // without completing, i.e. it panicked on its worker
                panic!("scoped task panicked on an exec worker");
            }
        }
    }

    /// Explicit graceful shutdown (identical to dropping the pool): close
    /// the queue, let workers drain, join them.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue: workers finish the backlog and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    loop {
        let job = {
            let guard = match rx.lock() {
                Ok(g) => g,
                // a peer panicked while holding the lock; the receiver
                // itself is still sound
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.recv()
        };
        match job {
            // A panicking task must not take the worker down with it: the
            // submitting scope observes the failure through its completion
            // channel; the pool thread lives on to serve later batches.
            Ok(job) => {
                let reg = obs::global();
                reg.exec_queue_depth.dec();
                let t0 = Instant::now();
                let _ = catch_unwind(AssertUnwindSafe(job));
                reg.exec_tasks.inc();
                reg.exec_task_us.record(t0.elapsed().as_micros() as u64);
            }
            Err(_) => break, // queue closed: graceful shutdown
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn spawn_runs_static_jobs_on_named_threads() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.num_threads(), 3);
        let (tx, rx) = mpsc::channel();
        for _ in 0..10 {
            let tx = tx.clone();
            pool.spawn(Box::new(move || {
                let name = std::thread::current()
                    .name()
                    .unwrap_or("")
                    .to_string();
                tx.send(name).unwrap();
            }));
        }
        drop(tx);
        let names: Vec<String> = rx.iter().collect();
        assert_eq!(names.len(), 10);
        assert!(names.iter().all(|n| n.starts_with("unq-exec-")));
    }

    #[test]
    fn run_scoped_borrows_caller_data_and_blocks_for_completion() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let partials: Vec<AtomicUsize> =
            (0..8).map(|_| AtomicUsize::new(0)).collect();
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|t| {
                let data = &data;
                let partials = &partials;
                Box::new(move || {
                    let sum: u64 =
                        data.iter().skip(t).step_by(8).copied().sum();
                    partials[t].store(sum as usize, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        // the call returned, so every partial must already be in place
        let total: usize =
            partials.iter().map(|p| p.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn pool_survives_a_panicking_scoped_task() {
        let pool = WorkerPool::new(2);
        let boom: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("task boom"))];
        let r = catch_unwind(AssertUnwindSafe(|| pool.run_scoped(boom)));
        assert!(r.is_err(), "scoped panic must propagate to the submitter");
        // the workers are still alive and serve the next batch
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let counter = &counter;
                Box::new(move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn shutdown_drains_backlog() {
        let counter = Arc::new(AtomicUsize::new(0));
        let pool = WorkerPool::new(1);
        for _ in 0..16 {
            let counter = counter.clone();
            pool.spawn(Box::new(move || {
                counter.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.shutdown(); // joins: every queued job must have run
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }
}
