//! Batch execution plans: `QueryBatch × IndexShard` scan fan-out and the
//! batched gather → decode rerank reduction.
//!
//! The planner turns a batch of per-query LUTs into one task per
//! `(query, shard)` pair, runs them on an [`Executor`], and reduces each
//! query's per-shard top-k lists with [`merge_topk`] **in shard order**,
//! which makes the result bit-identical to a sequential full-index scan
//! regardless of thread count or shard size (ties are broken by the
//! strict-less heap test plus ascending-id push order — see
//! `index::scan`).  The rerank stage gathers the candidate codes of the
//! *whole* query batch into one contiguous buffer and decodes them with a
//! single `reconstruct_batch` call, so UNQ's AOT decoder runs once per
//! batch instead of once per query.

use std::sync::mpsc;

use crate::index::scan::{merge_topk, scan_range_topk};
use crate::index::CompressedIndex;
use crate::linalg::{sq_l2, TopK};
use crate::quant::{Lut, Quantizer};

use super::pool::WorkerPool;

/// Where a plan's tasks run.
pub enum Executor {
    /// On the calling thread (`num_threads <= 1`): no pool, no overhead —
    /// the single-query `SearchEngine::search` path.
    Inline,
    /// On a persistent [`WorkerPool`].
    Pool(WorkerPool),
}

impl Executor {
    /// Inline for `num_threads <= 1`, a pool of that many workers above.
    pub fn new(num_threads: usize) -> Executor {
        if num_threads <= 1 {
            Executor::Inline
        } else {
            Executor::Pool(WorkerPool::new(num_threads))
        }
    }

    pub fn num_threads(&self) -> usize {
        match self {
            Executor::Inline => 1,
            Executor::Pool(p) => p.num_threads(),
        }
    }

    /// Resolve the `shard_rows` knob: 0 means "auto" — the whole index as
    /// one shard inline, ~4 shards per worker on a pool (enough slack for
    /// load balance without drowning in merge work).
    fn effective_shard_rows(&self, n: usize, shard_rows: usize) -> usize {
        if shard_rows != 0 {
            return shard_rows;
        }
        match self {
            Executor::Inline => 0,
            Executor::Pool(p) => n.div_ceil(p.num_threads() * 4).max(1024),
        }
    }

    /// Execute a `QueryBatch × IndexShard` scan plan: for every query `i`
    /// the global top-`ks[i]` `(score, id)` pairs sorted ascending,
    /// bit-identical to `scan_topk` over the full index.
    pub fn scan_batch(&self, luts: &[Lut], index: &CompressedIndex,
                      ks: &[usize], shard_rows: usize)
                      -> Vec<Vec<(f32, u32)>> {
        assert_eq!(luts.len(), ks.len(), "one k per query LUT");
        if luts.is_empty() {
            return Vec::new();
        }
        let shards =
            shard_ranges(index.n, self.effective_shard_rows(index.n, shard_rows));
        match self {
            Executor::Inline => luts
                .iter()
                .zip(ks)
                .map(|(lut, &k)| {
                    let parts: Vec<_> = shards
                        .iter()
                        .map(|&(lo, hi)| scan_range_topk(lut, index, lo, hi, k))
                        .collect();
                    merge_topk(parts, k)
                })
                .collect(),
            Executor::Pool(pool) => {
                let (nq, ns) = (luts.len(), shards.len());
                // full-capacity result channel: task sends never block
                let (tx, rx) = mpsc::sync_channel(nq * ns);
                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(nq * ns);
                for (qi, lut) in luts.iter().enumerate() {
                    let k = ks[qi];
                    for (si, &(lo, hi)) in shards.iter().enumerate() {
                        let tx = tx.clone();
                        tasks.push(Box::new(move || {
                            let part = scan_range_topk(lut, index, lo, hi, k);
                            let _ = tx.send((qi, si, part));
                        }));
                    }
                }
                drop(tx);
                pool.run_scoped(tasks);
                // reassemble the grid so each query merges its shards in
                // ascending-row order — the determinism requirement
                let mut grid: Vec<Vec<Option<Vec<(f32, u32)>>>> =
                    (0..nq).map(|_| (0..ns).map(|_| None).collect()).collect();
                while let Ok((qi, si, part)) = rx.try_recv() {
                    grid[qi][si] = Some(part);
                }
                grid.into_iter()
                    .zip(ks)
                    .map(|(parts, &k)| {
                        let parts: Vec<_> = parts
                            .into_iter()
                            .map(|p| p.expect("every shard task reported"))
                            .collect();
                        merge_topk(parts, k)
                    })
                    .collect()
            }
        }
    }
}

/// Partition `[0, n)` into contiguous shards of at most `shard_rows` rows
/// (`shard_rows == 0`: one shard spanning the whole index).
pub fn shard_ranges(n: usize, shard_rows: usize) -> Vec<(usize, usize)> {
    if n == 0 || shard_rows == 0 || shard_rows >= n {
        return vec![(0, n)];
    }
    let mut out = Vec::with_capacity(n.div_ceil(shard_rows));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + shard_rows).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Batched second stage: gather every query's candidate codes into one
/// contiguous buffer, decode them with a **single** `reconstruct_batch`
/// call (one AOT execution for UNQ), then rank each query's candidates by
/// exact `d1(q, i) = ‖q − reconstruct(i)‖²`.  Per query the result is
/// identical to the classic one-query rerank; quantizers without a
/// decoder keep scan order.
pub fn rerank_batch(quant: &dyn Quantizer, index: &CompressedIndex,
                    queries: &[&[f32]], candidates: &[Vec<u32>],
                    ks: &[usize]) -> Vec<Vec<u32>> {
    assert_eq!(queries.len(), candidates.len());
    assert_eq!(queries.len(), ks.len());
    let dim = quant.dim();
    let cb = index.stride;
    let total: usize = candidates.iter().map(|c| c.len()).sum();
    let mut codes = Vec::with_capacity(total * cb);
    for cands in candidates {
        for &id in cands {
            codes.extend_from_slice(index.code(id as usize));
        }
    }
    let mut recons = vec![0.0f32; total * dim];
    if !quant.reconstruct_batch(&codes, &mut recons) {
        // no decoder: keep scan order
        return candidates
            .iter()
            .zip(ks)
            .map(|(cands, &k)| cands.iter().take(k).copied().collect())
            .collect();
    }
    let mut out = Vec::with_capacity(queries.len());
    let mut off = 0usize;
    for ((&q, cands), &k) in queries.iter().zip(candidates).zip(ks) {
        let mut top = TopK::new(k.min(cands.len()));
        for (ci, &id) in cands.iter().enumerate() {
            let row = off + ci;
            let d = sq_l2(q, &recons[row * dim..(row + 1) * dim]);
            top.push(d, id);
        }
        off += cands.len();
        out.push(top.into_sorted().into_iter().map(|(_, id)| id).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::scan_topk;
    use crate::util::{prop, rng::SplitMix64};

    fn mk_index(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> =
            (0..n * stride).map(|_| rng.below(256) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut(stride: usize, seed: u64) -> Lut {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 256).map(|_| rng.next_f32() * 10.0).collect();
        Lut::Tables { m: stride, k: 256, tables, bias: 0.5 }
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        assert_eq!(shard_ranges(10, 0), vec![(0, 10)]);
        assert_eq!(shard_ranges(10, 100), vec![(0, 10)]);
        assert_eq!(shard_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(shard_ranges(0, 4), vec![(0, 0)]);
        let r = shard_ranges(1000, 1);
        assert_eq!(r.len(), 1000);
        assert_eq!(r[999], (999, 1000));
    }

    #[test]
    fn inline_scan_batch_matches_full_scan() {
        let idx = mk_index(777, 8, 1);
        let luts: Vec<Lut> = (0..3).map(|i| mk_lut(8, 10 + i)).collect();
        let ks = [7usize, 20, 100];
        let exec = Executor::new(1);
        let got = exec.scan_batch(&luts, &idx, &ks, 50);
        for (qi, lut) in luts.iter().enumerate() {
            assert_eq!(got[qi], scan_topk(lut, &idx, ks[qi]), "query {qi}");
        }
    }

    #[test]
    fn prop_pool_scan_equals_inline_over_thread_and_shard_grid() {
        // the acceptance property: any (num_threads, shard_rows) returns
        // bit-identical ids AND scores to the sequential full scan
        prop::forall_ok(
            1234,
            12,
            |r: &mut SplitMix64| {
                let n = 50 + r.below(900);
                let stride = 1 + r.below(10);
                let threads = 2 + r.below(3);
                let shard_rows = [0usize, 1, 13, 64, 300][r.below(5)];
                let k = 1 + r.below(40);
                (n, stride, threads, shard_rows, k, r.next_u64())
            },
            |&(n, stride, threads, shard_rows, k, seed)| {
                let idx = mk_index(n, stride, seed);
                let luts: Vec<Lut> =
                    (0..4).map(|i| mk_lut(stride, seed ^ (i + 1))).collect();
                let ks = vec![k; luts.len()];
                let pool = Executor::new(threads);
                let got = pool.scan_batch(&luts, &idx, &ks, shard_rows);
                let want = Executor::new(1).scan_batch(&luts, &idx, &ks, 0);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "threads={threads} shard_rows={shard_rows} diverged"
                    ))
                }
            },
        );
    }

    #[test]
    fn empty_batch_is_empty() {
        let idx = mk_index(10, 4, 3);
        let exec = Executor::new(2);
        assert!(exec.scan_batch(&[], &idx, &[], 0).is_empty());
    }
}
