//! Batch execution plans: generic `ScanTask` fan-out (the substrate under
//! both the flat `QueryBatch × IndexShard` plan and the IVF per-list
//! plans) and the batched gather → decode rerank reduction.
//!
//! The API is two entrypoints and one options struct: the flat planner
//! [`Executor::scan_batch`], the general [`Executor::run_scan_tasks`],
//! and a per-plan [`ScanSpec`] carrying every scan axis (kernel
//! precision, the 1-bit pre-filter, the metadata predicate filter) —
//! new axes land as `ScanSpec` fields, not as new entrypoint suffixes.
//!
//! The general unit is a [`ScanTask`]: score a contiguous row range of
//! one named index with one LUT and merge the partial top-k into an
//! output *slot*.  The flat plan emits one task per `(query, shard)`
//! pair with slot = query; the IVF plan (`crate::ivf`) emits one slot
//! per `(query, probed list)` pair so a small batch probing many lists
//! still fills the worker pool.  Per slot, partial results are reduced with
//! [`merge_topk`] **in task-submission order**, which for the flat plan
//! means ascending shard order — bit-identical to a sequential
//! full-index scan regardless of thread count or shard size (the
//! bounded heap orders candidates lexicographically on `(score, id)`,
//! so ties are decomposition-invariant — see `linalg::TopK`).  The
//! rerank stage gathers the candidate codes of
//! the *whole* query batch into one contiguous buffer and decodes them
//! with a single `reconstruct_batch` call, so UNQ's AOT decoder runs
//! once per batch instead of once per query.

use std::sync::mpsc;

use crate::config::ScanPrecision;
use crate::index::filter::{FilterBitmap, FilterPlan};
use crate::index::scan::{merge_topk, scan_range_topk_prec,
                         scan_range_topk_prefiltered};
use crate::index::CompressedIndex;
use crate::linalg::{sq_l2, TopK};
use crate::obs;
use crate::obs::span::Trace;
use crate::quant::{Lut, Quantizer, QuantizedLut};

use super::pool::WorkerPool;

/// Quantize the batch's LUTs once per plan (not per task): `None` marks
/// a LUT that scans through the exact f32 kernel — every LUT at
/// `ScanPrecision::F32`, and direct-scored (lattice) LUTs at any
/// precision, which have no table decomposition to quantize.  `U4`
/// additionally quantizes only when the codebook fits a 16-entry
/// register row (`k ≤ 16` codewords, `m ≤ 256`) — wider LUTs fall back
/// to the exact kernel through the same `None` machinery.
fn quantize_luts(luts: &[Lut], precision: ScanPrecision)
                 -> Vec<Option<QuantizedLut>> {
    match precision {
        ScanPrecision::F32 => vec![None; luts.len()],
        ScanPrecision::U16 => luts.iter().map(QuantizedLut::u16_from).collect(),
        ScanPrecision::U8 => luts.iter().map(QuantizedLut::u8_from).collect(),
        ScanPrecision::U4 => luts.iter().map(QuantizedLut::u4_from).collect(),
    }
}

/// The optional 1-bit pre-filter stage of a scan plan (DESIGN.md §9):
/// one query sketch per plan LUT (`None` entries never prune — residual
/// IVF LUTs, direct-scored LUTs) and the over-fetch margin.  A task
/// pre-filters only when its LUT has a sketch AND its index carries row
/// sketches; everything else falls through to the precision scan, so
/// threading a plan through sketchless indexes (streaming segments) is
/// always safe.
pub struct PrefilterPlan {
    /// Indexed like the plan's `luts`.
    pub qsketches: Vec<Option<u64>>,
    /// Candidates kept per task ≈ `k · margin` (floor `k`).
    pub margin: usize,
}

/// One task's scan: the pre-filtered exact path when the plan resolved
/// row sketches + a query sketch for it, the precision kernel otherwise
/// — either way threading the task's predicate bitmap into selection.
///
/// Also the per-task instrumentation point (rust/DESIGN.md §10): rows
/// are credited to the kernel that actually scans them — the exact f32
/// kernel for pre-filtered tasks and `None` qluts, the integer kernels
/// otherwise — in one bulk `fetch_add` per task, and the task gets a
/// `scan_task` span carrying its row count when a trace is live.
/// Predicate pruning charges `filter.rows_pruned` with the range's
/// filtered-out count, and a range with nothing admitted skips its
/// kernel entirely (an empty part merges as a no-op).
fn scan_task_part(lut: &Lut, qlut: Option<&QuantizedLut>,
                  ix: &CompressedIndex, lo: usize, hi: usize, k: usize,
                  pf: Option<(&[u64], u64, usize)>,
                  filter: Option<&FilterBitmap>) -> Vec<(f32, u32)> {
    let reg = obs::global();
    let rows = (hi - lo) as u64;
    reg.scan_tasks.inc();
    if let Some(f) = filter {
        let admitted = f.admitted_in(lo, hi) as u64;
        reg.filter_rows_pruned.add(rows - admitted);
        if admitted == 0 {
            return Vec::new();
        }
    }
    match (pf.is_some(), qlut) {
        (true, _) | (false, None) => reg.scan_rows_f32.add(rows),
        (false, Some(QuantizedLut::U16 { .. })) => {
            reg.scan_rows_u16.add(rows)
        }
        (false, Some(QuantizedLut::U8 { .. })) => reg.scan_rows_u8.add(rows),
        (false, Some(QuantizedLut::U4 { .. })) => reg.scan_rows_u4.add(rows),
    }
    let mut span = crate::span!("scan_task");
    span.add_rows(rows);
    match pf {
        Some((sketches, qsketch, margin)) => scan_range_topk_prefiltered(
            lut, ix, sketches, qsketch, lo, hi, k, margin, filter),
        None => scan_range_topk_prec(lut, qlut, ix, lo, hi, k, filter),
    }
}

/// Where a plan's tasks run.
pub enum Executor {
    /// On the calling thread (`num_threads <= 1`): no pool, no overhead —
    /// the single-query `SearchEngine::search` path.
    Inline,
    /// On a persistent [`WorkerPool`].
    Pool(WorkerPool),
}

impl Executor {
    /// Inline for `num_threads <= 1`, a pool of that many workers above.
    pub fn new(num_threads: usize) -> Executor {
        if num_threads <= 1 {
            Executor::Inline
        } else {
            Executor::Pool(WorkerPool::new(num_threads))
        }
    }

    pub fn num_threads(&self) -> usize {
        match self {
            Executor::Inline => 1,
            Executor::Pool(p) => p.num_threads(),
        }
    }

    /// Resolve the `shard_rows` knob: 0 means "auto" — the whole index as
    /// one shard inline, ~4 shards per worker on a pool (enough slack for
    /// load balance without drowning in merge work).  `n` is the total
    /// row count the plan will scan (planners over sub-ranges, like IVF,
    /// pass their whole index so shard size is stable across lists).
    pub fn effective_shard_rows(&self, n: usize, shard_rows: usize) -> usize {
        if shard_rows != 0 {
            return shard_rows;
        }
        match self {
            Executor::Inline => 0,
            Executor::Pool(p) => n.div_ceil(p.num_threads() * 4).max(1024),
        }
    }

    /// Execute a `QueryBatch × IndexShard` scan plan under `spec`: for
    /// every query `i` the global top-`ks[i]` `(score, id)` pairs sorted
    /// ascending — at [`ScanSpec::default`], bit-identical to
    /// `scan_topk` over the full index.  (A thin planner over
    /// [`Self::run_scan_tasks`]: slot = query, index 0, tasks in
    /// ascending shard order.)
    pub fn scan_batch(&self, luts: &[Lut], index: &CompressedIndex,
                      ks: &[usize], shard_rows: usize, spec: &ScanSpec)
                      -> Vec<Vec<(f32, u32)>> {
        assert_eq!(luts.len(), ks.len(), "one k per query LUT");
        if luts.is_empty() {
            return Vec::new();
        }
        let shards =
            shard_ranges(index.n, self.effective_shard_rows(index.n, shard_rows));
        let mut tasks = Vec::with_capacity(luts.len() * shards.len());
        for qi in 0..luts.len() {
            for &(lo, hi) in &shards {
                tasks.push(ScanTask { index: 0, slot: qi, lut: qi, lo, hi });
            }
        }
        self.run_scan_tasks(luts, &[index], ks, &tasks, spec)
    }

    /// Execute an arbitrary [`ScanTask`] plan under `spec`: for every
    /// slot `s`, the merged top-`ks[s]` `(score, id)` pairs over that
    /// slot's tasks, sorted ascending.  Every task names the index it
    /// scans, so one plan can fan out over several code matrices at
    /// once — the streaming path plans `(query, segment[, list])` slots
    /// across all sealed segments plus the active tail in a single
    /// submission (`index::segment`), keeping the worker pool full even
    /// when the row count is spread over many small segments.  Returned
    /// row ids are **local to each task's index**; keep slots
    /// index-pure if the caller needs to map them back (the streaming
    /// reduce does).  Slots with no tasks yield empty results.
    ///
    /// Determinism contract: per slot, partial results merge in
    /// task-submission order on every executor, so a plan whose tasks
    /// cover ascending row ranges reproduces the sequential scan's
    /// tie-breaking exactly.  Quantized LUTs are built **once per plan**
    /// (per-query for the flat plan, per probed-list slot for IVF
    /// residual plans) and shared by every task referencing that LUT;
    /// each task selects with integer scores and re-scores its
    /// survivors exactly, so the per-slot merge always compares exact
    /// f32 scores under the `(score, id)` total order.  Pre-filtered
    /// tasks (resolved per task — needs BOTH a query sketch for the
    /// LUT and row sketches on the index) and plain-kernel tasks mix
    /// freely within one slot for the same reason.
    ///
    /// Plans are validated at submission: a task naming an out-of-range
    /// slot/LUT/index/row panics here with the offending task named,
    /// not with a bare index-out-of-bounds inside a worker thread.
    pub fn run_scan_tasks(&self, luts: &[Lut],
                          indexes: &[&CompressedIndex], ks: &[usize],
                          tasks: &[ScanTask], spec: &ScanSpec)
                          -> Vec<Vec<(f32, u32)>> {
        validate_plan(luts, indexes, ks, tasks, spec);
        let qluts = quantize_luts(luts, spec.precision);
        let task_pf = |t: &ScanTask| -> Option<(&[u64], u64, usize)> {
            let p = spec.prefilter?;
            let qs = p.qsketches[t.lut]?;
            let sk = indexes[t.index].sketches.as_deref()?;
            Some((sk, qs, p.margin))
        };
        let task_filter = |t: &ScanTask| -> Option<&FilterBitmap> {
            spec.filter.map(|fp| fp.bitmap(t.index))
        };
        let nslots = ks.len();
        // per-slot ordinal of each task: its merge position within the slot
        let mut counts = vec![0usize; nslots];
        let ords: Vec<usize> = tasks
            .iter()
            .map(|t| {
                let o = counts[t.slot];
                counts[t.slot] += 1;
                o
            })
            .collect();
        match self {
            Executor::Inline => {
                let mut parts: Vec<Vec<Vec<(f32, u32)>>> =
                    counts.iter().map(|&c| Vec::with_capacity(c)).collect();
                {
                    let _scan_span = crate::span!("scan");
                    for t in tasks {
                        parts[t.slot].push(scan_task_part(
                            &luts[t.lut], qluts[t.lut].as_ref(),
                            indexes[t.index], t.lo, t.hi, ks[t.slot],
                            task_pf(t), task_filter(t)));
                    }
                }
                let _merge_span = crate::span!("merge");
                parts
                    .into_iter()
                    .zip(ks)
                    .map(|(p, &k)| merge_topk(p, k))
                    .collect()
            }
            Executor::Pool(pool) => {
                // full-capacity result channel: task sends never block
                let (tx, rx) = mpsc::sync_channel(tasks.len().max(1));
                let scan_span = crate::span!("scan");
                // captured under the open "scan" span so task spans on
                // worker threads parent to THIS plan's tree (and to no
                // concurrent plan's) — None when tracing is off
                let handle = Trace::current_handle();
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(tasks.len());
                for (ti, t) in tasks.iter().enumerate() {
                    let tx = tx.clone();
                    let lut = &luts[t.lut];
                    let qlut = qluts[t.lut].as_ref();
                    let ix = indexes[t.index];
                    let k = ks[t.slot];
                    let (slot, ord) = (t.slot, ords[ti]);
                    let (lo, hi) = (t.lo, t.hi);
                    let pf = task_pf(t);
                    let fb = task_filter(t);
                    let handle = handle.clone();
                    jobs.push(Box::new(move || {
                        let _install = handle.as_ref().map(|h| h.install());
                        let part = scan_task_part(lut, qlut, ix, lo, hi, k,
                                                  pf, fb);
                        let _ = tx.send((slot, ord, part));
                    }));
                }
                drop(tx);
                pool.run_scoped(jobs);
                drop(scan_span);
                let _merge_span = crate::span!("merge");
                // reassemble the grid so each slot merges its parts in
                // submission order — the determinism requirement
                let mut grid: Vec<Vec<Option<Vec<(f32, u32)>>>> = counts
                    .iter()
                    .map(|&c| (0..c).map(|_| None).collect())
                    .collect();
                while let Ok((slot, ord, part)) = rx.try_recv() {
                    grid[slot][ord] = Some(part);
                }
                grid.into_iter()
                    .zip(ks)
                    .map(|(parts, &k)| {
                        let parts: Vec<_> = parts
                            .into_iter()
                            .map(|p| p.expect("every scan task reported"))
                            .collect();
                        merge_topk(parts, k)
                    })
                    .collect()
            }
        }
    }
}

/// Per-plan scan options, consumed by both executor entrypoints
/// ([`Executor::scan_batch`] and [`Executor::run_scan_tasks`]).  Each
/// prior scan axis minted a new positional entrypoint suffix
/// (`_prec`, `_pre`, …); they all live here now, and new axes land as
/// fields.  [`ScanSpec::default`] is the classic exact scan: f32
/// kernel, no pre-filter, no predicate.
#[derive(Clone, Copy, Default)]
pub struct ScanSpec<'a> {
    /// Scan kernel precision (DESIGN.md §6): `F32` runs the exact
    /// kernel; `U16`/`U8`/`U4` quantize each LUT once per plan and run
    /// the blocked integer kernels with exact f32 survivor re-scoring.
    pub precision: ScanPrecision,
    /// Optional 1-bit sketch pre-filter stage (DESIGN.md §9): tasks
    /// whose LUT has a query sketch AND whose index carries row
    /// sketches prune by Hamming distance before exact scoring.
    pub prefilter: Option<&'a PrefilterPlan>,
    /// Optional metadata predicate (DESIGN.md §13), compiled to one row
    /// bitmap per plan index: tasks consult their index's bitmap
    /// *inside* the selection loop, so filtered rows never enter the
    /// top-k heap and filtered search equals the search over the
    /// admitted subset exactly — at every precision.
    pub filter: Option<&'a FilterPlan>,
}

/// One unit of scan work: score rows `[lo, hi)` of `indexes[index]`
/// with `luts[lut]`, keep the top `ks[slot]`, and merge into output
/// slot `slot` (merge order across a slot's tasks = submission order;
/// row ids in a slot's results are local to that task's index).
#[derive(Clone, Copy, Debug)]
pub struct ScanTask {
    pub index: usize,
    pub slot: usize,
    pub lut: usize,
    pub lo: usize,
    pub hi: usize,
}

/// Submission-time plan validation.  A malformed task used to surface
/// as a bare index-out-of-bounds panic deep inside a worker thread;
/// every cross-reference is checked up front instead, with a message
/// naming the offending task.
fn validate_plan(luts: &[Lut], indexes: &[&CompressedIndex], ks: &[usize],
                 tasks: &[ScanTask], spec: &ScanSpec) {
    if let Some(p) = spec.prefilter {
        assert_eq!(p.qsketches.len(), luts.len(),
                   "prefilter plan carries {} query sketches for {} LUTs",
                   p.qsketches.len(), luts.len());
    }
    if let Some(fp) = spec.filter {
        assert_eq!(fp.bitmaps.len(), indexes.len(),
                   "filter plan carries {} bitmaps for {} indexes",
                   fp.bitmaps.len(), indexes.len());
        for (i, (bm, ix)) in fp.bitmaps.iter().zip(indexes).enumerate() {
            assert_eq!(bm.len(), ix.n,
                       "filter bitmap {i} covers {} rows of a {}-row index",
                       bm.len(), ix.n);
        }
    }
    for (ti, t) in tasks.iter().enumerate() {
        assert!(t.index < indexes.len(),
                "scan task {ti} names index {} of a {}-index plan",
                t.index, indexes.len());
        assert!(t.slot < ks.len(),
                "scan task {ti} names slot {} of a {}-slot plan",
                t.slot, ks.len());
        assert!(t.lut < luts.len(),
                "scan task {ti} names LUT {} of a {}-LUT plan",
                t.lut, luts.len());
        let n = indexes[t.index].n;
        assert!(t.lo <= t.hi && t.hi <= n,
                "scan task {ti} scans rows [{}, {}) of a {n}-row index",
                t.lo, t.hi);
    }
}

/// Partition `[0, n)` into contiguous shards of at most `shard_rows` rows
/// (`shard_rows == 0`: one shard spanning the whole index).
pub fn shard_ranges(n: usize, shard_rows: usize) -> Vec<(usize, usize)> {
    shard_ranges_in(0, n, shard_rows)
}

/// Partition an arbitrary row range `[lo, hi)` into contiguous shards of
/// at most `shard_rows` rows (`shard_rows == 0`: the whole range as one
/// shard) — the per-list variant the IVF planner shards with.
pub fn shard_ranges_in(lo: usize, hi: usize, shard_rows: usize)
                       -> Vec<(usize, usize)> {
    let len = hi.saturating_sub(lo);
    if len == 0 || shard_rows == 0 || shard_rows >= len {
        return vec![(lo, hi.max(lo))];
    }
    let mut out = Vec::with_capacity(len.div_ceil(shard_rows));
    let mut cur = lo;
    while cur < hi {
        let next = (cur + shard_rows).min(hi);
        out.push((cur, next));
        cur = next;
    }
    out
}

/// Batched second stage: gather every query's candidate codes into one
/// contiguous buffer, decode them with a **single** `reconstruct_batch`
/// call (one AOT execution for UNQ), then rank each query's candidates by
/// exact `d1(q, i) = ‖q − reconstruct(i)‖²`.  Per query the result is
/// identical to the classic one-query rerank; quantizers without a
/// decoder keep scan order.
pub fn rerank_batch(quant: &dyn Quantizer, index: &CompressedIndex,
                    queries: &[&[f32]], candidates: &[Vec<u32>],
                    ks: &[usize]) -> Vec<Vec<u32>> {
    assert_eq!(queries.len(), candidates.len());
    assert_eq!(queries.len(), ks.len());
    let dim = quant.dim();
    let cb = index.stride;
    let total: usize = candidates.iter().map(|c| c.len()).sum();
    let mut span = crate::span!("rerank");
    span.add_rows(total as u64);
    let mut codes = Vec::with_capacity(total * cb);
    for cands in candidates {
        for &id in cands {
            codes.extend_from_slice(index.code(id as usize));
        }
    }
    let mut recons = vec![0.0f32; total * dim];
    if !quant.reconstruct_batch(&codes, &mut recons) {
        // no decoder: keep scan order
        return candidates
            .iter()
            .zip(ks)
            .map(|(cands, &k)| cands.iter().take(k).copied().collect())
            .collect();
    }
    let mut out = Vec::with_capacity(queries.len());
    let mut off = 0usize;
    for ((&q, cands), &k) in queries.iter().zip(candidates).zip(ks) {
        let mut top = TopK::new(k.min(cands.len()));
        for (ci, &id) in cands.iter().enumerate() {
            let row = off + ci;
            let d = sq_l2(q, &recons[row * dim..(row + 1) * dim]);
            top.push(d, id);
        }
        off += cands.len();
        out.push(top.into_sorted().into_iter().map(|(_, id)| id).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::scan_topk;
    use crate::util::{prop, rng::SplitMix64};

    fn mk_index(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> =
            (0..n * stride).map(|_| rng.below(256) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut(stride: usize, seed: u64) -> Lut {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 256).map(|_| rng.next_f32() * 10.0).collect();
        Lut::Tables { m: stride, k: 256, tables, bias: 0.5 }
    }

    /// 16-codeword twin of `mk_index`/`mk_lut`: codes < 16 and 16-wide
    /// tables, so `ScanPrecision::U4` quantizes instead of falling back.
    fn mk_index16(n: usize, stride: usize, seed: u64) -> CompressedIndex {
        let mut rng = SplitMix64::new(seed);
        let codes: Vec<u8> =
            (0..n * stride).map(|_| rng.below(16) as u8).collect();
        CompressedIndex::from_codes(n, stride, codes)
    }

    fn mk_lut16(stride: usize, seed: u64) -> Lut {
        let mut rng = SplitMix64::new(seed);
        let tables: Vec<f32> =
            (0..stride * 16).map(|_| rng.next_f32() * 10.0).collect();
        Lut::Tables { m: stride, k: 16, tables, bias: 0.5 }
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        assert_eq!(shard_ranges(10, 0), vec![(0, 10)]);
        assert_eq!(shard_ranges(10, 100), vec![(0, 10)]);
        assert_eq!(shard_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(shard_ranges(0, 4), vec![(0, 0)]);
        let r = shard_ranges(1000, 1);
        assert_eq!(r.len(), 1000);
        assert_eq!(r[999], (999, 1000));
    }

    #[test]
    fn inline_scan_batch_matches_full_scan() {
        let idx = mk_index(777, 8, 1);
        let luts: Vec<Lut> = (0..3).map(|i| mk_lut(8, 10 + i)).collect();
        let ks = [7usize, 20, 100];
        let exec = Executor::new(1);
        let got = exec.scan_batch(&luts, &idx, &ks, 50, &ScanSpec::default());
        for (qi, lut) in luts.iter().enumerate() {
            assert_eq!(got[qi], scan_topk(lut, &idx, ks[qi]), "query {qi}");
        }
    }

    #[test]
    fn prop_pool_scan_equals_inline_over_thread_and_shard_grid() {
        // the acceptance property: any (num_threads, shard_rows) returns
        // bit-identical ids AND scores to the sequential full scan
        prop::forall_ok(
            1234,
            12,
            |r: &mut SplitMix64| {
                let n = 50 + r.below(900);
                let stride = 1 + r.below(10);
                let threads = 2 + r.below(3);
                let shard_rows = [0usize, 1, 13, 64, 300][r.below(5)];
                let k = 1 + r.below(40);
                (n, stride, threads, shard_rows, k, r.next_u64())
            },
            |&(n, stride, threads, shard_rows, k, seed)| {
                let idx = mk_index(n, stride, seed);
                let luts: Vec<Lut> =
                    (0..4).map(|i| mk_lut(stride, seed ^ (i + 1))).collect();
                let ks = vec![k; luts.len()];
                let pool = Executor::new(threads);
                let spec = ScanSpec::default();
                let got = pool.scan_batch(&luts, &idx, &ks, shard_rows, &spec);
                let want =
                    Executor::new(1).scan_batch(&luts, &idx, &ks, 0, &spec);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "threads={threads} shard_rows={shard_rows} diverged"
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_pool_scan_equals_inline_at_every_precision() {
        // the precision axis composes with the executor: for each of
        // f32/u16/u8, a pool of any size returns results bit-identical
        // to the inline executor at the SAME shard decomposition.  (At
        // f32 shard size doesn't matter either — the existing grid
        // property — but at u16/u8 per-shard integer selection may
        // legitimately swap candidates *inside the quantization margin*
        // when the decomposition changes, and `shard_rows = 0` auto-
        // sizes from the pool, so this property pins an explicit
        // shard_rows and varies only the executor — see DESIGN.md §6.)
        prop::forall_ok(
            5150,
            10,
            |r: &mut SplitMix64| {
                let n = 50 + r.below(700);
                let stride = 1 + r.below(10);
                let threads = 2 + r.below(3);
                let shard_rows = [0usize, 1, 13, 64, 300][r.below(5)];
                let k = 1 + r.below(30);
                let prec = [ScanPrecision::F32, ScanPrecision::U16,
                            ScanPrecision::U8, ScanPrecision::U4]
                    [r.below(4)];
                (n, stride, threads, shard_rows, k, prec, r.next_u64())
            },
            |&(n, stride, threads, shard_rows, k, prec, seed)| {
                // U4 gets 16-codeword data so it exercises the real 4-bit
                // kernel rather than the wide-codebook f32 fallback
                let u4 = prec == ScanPrecision::U4;
                let mut idx = if u4 {
                    mk_index16(n, stride, seed)
                } else {
                    mk_index(n, stride, seed)
                };
                if seed % 2 == 0 {
                    idx.ensure_packed();
                }
                let luts: Vec<Lut> = (0..3)
                    .map(|i| if u4 {
                        mk_lut16(stride, seed ^ (i + 9))
                    } else {
                        mk_lut(stride, seed ^ (i + 9))
                    })
                    .collect();
                let ks = vec![k; luts.len()];
                let pool = Executor::new(threads);
                // same explicit shard size on both sides: auto-sizing
                // differs between pool and inline by design
                let rows = if shard_rows == 0 { n } else { shard_rows };
                let spec = ScanSpec { precision: prec, ..Default::default() };
                let got = pool.scan_batch(&luts, &idx, &ks, rows, &spec);
                let want =
                    Executor::new(1).scan_batch(&luts, &idx, &ks, rows, &spec);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "{prec:?} threads={threads} shard_rows={rows} \
                         pool diverged from inline"
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_results_bit_identical_with_tracing_on_at_every_precision() {
        // the observability overhead contract (rust/DESIGN.md §10):
        // tracing is a read-only side channel, so enabling it changes
        // NOTHING about results — same ids, same scores, bit for bit —
        // at every precision, executor, and shard decomposition.  The
        // collected trace must also account for every scanned row
        // exactly: tasks cover each of the batch's luts.len() queries
        // over all n rows once.
        prop::forall_ok(
            7207,
            10,
            |r: &mut SplitMix64| {
                let n = 50 + r.below(600);
                let stride = 1 + r.below(8);
                let threads = 1 + r.below(4);
                let shard_rows = [1usize, 13, 64, 300][r.below(4)];
                let k = 1 + r.below(25);
                let prec = [ScanPrecision::F32, ScanPrecision::U16,
                            ScanPrecision::U8, ScanPrecision::U4]
                    [r.below(4)];
                (n, stride, threads, shard_rows, k, prec, r.next_u64())
            },
            |&(n, stride, threads, shard_rows, k, prec, seed)| {
                let u4 = prec == ScanPrecision::U4;
                let idx = if u4 {
                    mk_index16(n, stride, seed)
                } else {
                    mk_index(n, stride, seed)
                };
                let luts: Vec<Lut> = (0..3)
                    .map(|i| if u4 {
                        mk_lut16(stride, seed ^ (i + 3))
                    } else {
                        mk_lut(stride, seed ^ (i + 3))
                    })
                    .collect();
                let ks = vec![k; luts.len()];
                let exec = Executor::new(threads);
                let spec = ScanSpec { precision: prec, ..Default::default() };
                let want =
                    exec.scan_batch(&luts, &idx, &ks, shard_rows, &spec);
                let (trace, root) = crate::obs::Trace::begin("query");
                let got =
                    exec.scan_batch(&luts, &idx, &ks, shard_rows, &spec);
                drop(root);
                if got != want {
                    return Err(format!(
                        "{prec:?} threads={threads} shard_rows={shard_rows} \
                         results changed under tracing"
                    ));
                }
                let scanned = trace.rows("scan_task");
                let expect = (luts.len() * n) as u64;
                if scanned != expect {
                    return Err(format!(
                        "trace accounted {scanned} rows, scanned {expect}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_traces_on_shared_pool_do_not_cross_leak() {
        // two queries tracing simultaneously over ONE worker pool: each
        // trace must account exactly its own workload's rows (leakage
        // across the shared workers would over-count one side and
        // under-count the other).  The per-job TraceHandle install is
        // what this pins — workers interleave jobs from both traces.
        let exec = Executor::new(3);
        let idx_a = mk_index(400, 4, 91);
        let idx_b = mk_index(250, 4, 92);
        std::thread::scope(|s| {
            let ha = s.spawn(|| {
                let luts = vec![mk_lut(4, 7)];
                let ks = [9usize];
                let (trace, root) = crate::obs::Trace::begin("qa");
                for _ in 0..8 {
                    let _ = exec.scan_batch(&luts, &idx_a, &ks, 32,
                                            &ScanSpec::default());
                }
                drop(root);
                trace.rows("scan_task")
            });
            let hb = s.spawn(|| {
                let luts = vec![mk_lut(4, 8)];
                let ks = [9usize];
                let (trace, root) = crate::obs::Trace::begin("qb");
                for _ in 0..8 {
                    let _ = exec.scan_batch(&luts, &idx_b, &ks, 32,
                                            &ScanSpec::default());
                }
                drop(root);
                trace.rows("scan_task")
            });
            assert_eq!(ha.join().unwrap(), 8 * 400);
            assert_eq!(hb.join().unwrap(), 8 * 250);
        });
    }

    #[test]
    fn multi_index_tasks_match_per_index_scans_merged() {
        // two indexes, slots spanning both: slot 0 covers the whole of
        // index 0 AND index 1 with lut 0 (row ids collide across indexes
        // by design — the caller keeps slots index-pure when it needs to
        // map rows back; here we only check the merged score multiset),
        // slot 1 covers index 1 only with lut 1
        let ix0 = mk_index(300, 5, 21);
        let ix1 = mk_index(170, 5, 22);
        let luts: Vec<Lut> = (0..2).map(|i| mk_lut(5, 60 + i)).collect();
        let tasks = vec![
            ScanTask { index: 0, slot: 0, lut: 0, lo: 0, hi: 300 },
            ScanTask { index: 1, slot: 0, lut: 0, lo: 0, hi: 170 },
            ScanTask { index: 1, slot: 1, lut: 1, lo: 40, hi: 160 },
        ];
        let ks = [12usize, 6];
        for threads in [1usize, 3] {
            let exec = Executor::new(threads);
            let got = exec.run_scan_tasks(&luts, &[&ix0, &ix1], &ks, &tasks,
                                          &ScanSpec::default());
            // slot 0: merge of both full scans under (score, id)
            let want0 = merge_topk(vec![
                scan_topk(&luts[0], &ix0, 12),
                scan_topk(&luts[0], &ix1, 12),
            ], 12);
            assert_eq!(got[0], want0, "threads={threads} slot 0");
            let want1 = crate::index::scan::scan_range_topk(
                &luts[1], &ix1, 40, 160, 6, None);
            assert_eq!(got[1], want1, "threads={threads} slot 1");
        }
    }

    #[test]
    fn prefiltered_batch_matches_plain_scan_at_full_keep_on_any_executor() {
        // keep ≥ every shard ⇒ the pre-filter stage must be a no-op, on
        // the inline executor and on pools alike; sketch content is
        // irrelevant at full keep so zeros suffice
        let mut idx = mk_index(400, 6, 77);
        idx.sketches = Some(vec![0u64; 400]);
        let luts: Vec<Lut> = (0..3).map(|i| mk_lut(6, 80 + i)).collect();
        let ks = vec![9usize; luts.len()];
        let pre = PrefilterPlan {
            qsketches: luts.iter().map(|_| Some(0u64)).collect(),
            margin: 10_000,
        };
        let want = Executor::new(1).scan_batch(&luts, &idx, &ks, 128,
                                               &ScanSpec::default());
        for threads in [1usize, 3] {
            let spec = ScanSpec { prefilter: Some(&pre),
                                  ..Default::default() };
            let got = Executor::new(threads)
                .scan_batch(&luts, &idx, &ks, 128, &spec);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn prefilter_skips_tasks_without_sketches() {
        // a plan with a PrefilterPlan but a sketchless index must fall
        // through to the precision scan on every task (the streaming
        // segment guarantee)
        let idx = mk_index(300, 5, 91);
        let luts = vec![mk_lut(5, 92)];
        let ks = [11usize];
        let pre = PrefilterPlan { qsketches: vec![Some(7)], margin: 2 };
        let want = Executor::new(1).scan_batch(&luts, &idx, &ks, 64,
                                               &ScanSpec::default());
        let spec = ScanSpec { prefilter: Some(&pre), ..Default::default() };
        let got = Executor::new(1).scan_batch(&luts, &idx, &ks, 64, &spec);
        assert_eq!(got, want);
    }

    #[test]
    fn empty_batch_is_empty() {
        let idx = mk_index(10, 4, 3);
        let exec = Executor::new(2);
        assert!(exec.scan_batch(&[], &idx, &[], 0, &ScanSpec::default())
                    .is_empty());
    }

    #[test]
    fn shard_ranges_in_covers_subrange_exactly_once() {
        assert_eq!(shard_ranges_in(5, 5, 4), vec![(5, 5)]);
        assert_eq!(shard_ranges_in(5, 20, 0), vec![(5, 20)]);
        assert_eq!(shard_ranges_in(5, 20, 100), vec![(5, 20)]);
        assert_eq!(shard_ranges_in(5, 17, 5),
                   vec![(5, 10), (10, 15), (15, 17)]);
    }

    #[test]
    fn scan_tasks_slot_merge_matches_direct_range_scans() {
        // a hand-built plan: slot 0 scans [0,300)+[300,500) with lut 0,
        // slot 1 scans only [100,400) with lut 1, slot 2 has no tasks
        let idx = mk_index(500, 6, 9);
        let luts: Vec<Lut> = (0..2).map(|i| mk_lut(6, 40 + i)).collect();
        let tasks = vec![
            ScanTask { index: 0, slot: 0, lut: 0, lo: 0, hi: 300 },
            ScanTask { index: 0, slot: 1, lut: 1, lo: 100, hi: 400 },
            ScanTask { index: 0, slot: 0, lut: 0, lo: 300, hi: 500 },
        ];
        let ks = [9usize, 14, 5];
        for threads in [1usize, 3] {
            let exec = Executor::new(threads);
            let got = exec.run_scan_tasks(&luts, &[&idx], &ks, &tasks,
                                          &ScanSpec::default());
            assert_eq!(got[0], scan_topk(&luts[0], &idx, 9),
                       "threads={threads} slot 0");
            assert_eq!(got[1],
                       crate::index::scan::scan_range_topk(
                           &luts[1], &idx, 100, 400, 14, None),
                       "threads={threads} slot 1");
            assert!(got[2].is_empty(), "threads={threads} empty slot");
        }
    }

    #[test]
    fn malformed_plans_panic_at_submission_with_context() {
        // the PR-10 bugfix: a task referencing a nonexistent
        // slot/LUT/index/row must be rejected at submission with the
        // offending task named, not explode inside a worker thread
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let idx = mk_index(100, 4, 44);
        let luts = vec![mk_lut(4, 45)];
        let ks = [5usize];
        let msg = |t: ScanTask| -> String {
            let err = catch_unwind(AssertUnwindSafe(|| {
                Executor::new(1).run_scan_tasks(&luts, &[&idx], &ks, &[t],
                                                &ScanSpec::default())
            }))
            .expect_err("malformed plan must panic");
            err.downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default()
        };
        let ok = ScanTask { index: 0, slot: 0, lut: 0, lo: 0, hi: 100 };
        assert!(msg(ScanTask { slot: 3, ..ok }).contains("slot 3"));
        assert!(msg(ScanTask { lut: 2, ..ok }).contains("LUT 2"));
        assert!(msg(ScanTask { index: 1, ..ok }).contains("index 1"));
        assert!(msg(ScanTask { lo: 50, hi: 200, ..ok }).contains("200"));
        // and the well-formed task still runs
        let got = Executor::new(1).run_scan_tasks(&luts, &[&idx], &ks, &[ok],
                                                  &ScanSpec::default());
        assert_eq!(got[0], scan_topk(&luts[0], &idx, 5));
    }

    #[test]
    fn filtered_scan_batch_matches_kernel_on_any_executor() {
        use crate::index::filter::{Filter, FilterBitmap, FilterPlan};
        let mut idx = mk_index(500, 6, 71);
        idx.set_tags((0..500).map(|i| (i % 2) as u64).collect());
        let luts: Vec<Lut> = (0..2).map(|i| mk_lut(6, 72 + i)).collect();
        let ks = vec![11usize; luts.len()];
        let plan = FilterPlan::compile(&Filter::TagEq(1), &[&idx]);
        let spec = ScanSpec { filter: Some(&plan), ..Default::default() };
        let bm = FilterBitmap::build(&Filter::TagEq(1), &idx);
        for threads in [1usize, 3] {
            let got = Executor::new(threads)
                .scan_batch(&luts, &idx, &ks, 64, &spec);
            for (qi, lut) in luts.iter().enumerate() {
                let want = crate::index::scan::scan_range_topk(
                    lut, &idx, 0, 500, ks[qi], Some(&bm));
                assert_eq!(got[qi], want, "threads={threads} query {qi}");
            }
        }
    }

    #[test]
    fn zero_selectivity_filter_yields_empty_results_and_counts_pruned() {
        use crate::index::filter::{Filter, FilterPlan};
        let mut idx = mk_index(300, 5, 81);
        idx.set_tags(vec![7u64; 300]);
        let luts = vec![mk_lut(5, 82)];
        let ks = [9usize];
        let plan = FilterPlan::compile(&Filter::TagEq(8), &[&idx]);
        let spec = ScanSpec { filter: Some(&plan), ..Default::default() };
        let before = obs::global().filter_rows_pruned.get();
        let got = Executor::new(1).scan_batch(&luts, &idx, &ks, 0, &spec);
        assert_eq!(got, vec![Vec::<(f32, u32)>::new()]);
        let pruned = obs::global().filter_rows_pruned.get() - before;
        assert!(pruned >= 300, "pruned only {pruned} rows");
    }
}
