//! Crate-wide observability: a global metrics registry, hierarchical
//! span tracing, and snapshot/export plumbing (rust/DESIGN.md §10).
//!
//! Three pieces:
//!
//! * [`hist`] — the √2-bucketed [`LatencyHistogram`], generalized out of
//!   the coordinator (whose aggregate [`crate::coordinator::Metrics`]
//!   uses it directly).
//! * [`span`] — per-query span trees behind the `crate::span!` macro:
//!   a single relaxed load + branch when no trace is live, a full
//!   route→probe→scan→…→rerank EXPLAIN tree when one is
//!   (`unq search --explain`, coordinator `trace` payloads).
//! * this module — the [`Registry`] of named counter/gauge/histogram
//!   families, its process-wide instance ([`global`]), and
//!   [`MetricsSnapshot`] (JSON round-trip + delta subtraction so
//!   benches bracket a run and attach counter evidence).
//!
//! Metric recording is always on: every probe is a relaxed atomic on a
//! `&'static` field — no locks, no allocation, no feature flags — and
//! instrumentation is amortized per *task* (rows added in bulk, kernel
//! dispatch counted once per scan call), so the steady-state cost is a
//! handful of `fetch_add`s per scan task.  Tracing, which does
//! allocate, is off unless a query asks for it (`UNQ_TRACE=1` /
//! `SearchConfig::trace` / `--explain`).
//!
//! Adding a metric family is a three-line change here: add the field,
//! name it in [`Registry::snapshot`], done — every consumer
//! (`unq stats`, bench brackets, the ingest summary) picks it up from
//! the snapshot automatically.

pub mod hist;
pub mod span;

pub use hist::{HistSnapshot, LatencyHistogram};
pub use span::{SpanGuard, Trace, TraceHandle};
// the `span!` macro exports at crate root (macro_rules); re-export it
// here so call sites can write `obs::span!("scan")` as well — macros
// and the `span` module live in different namespaces, like `std::vec`
pub use crate::span;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// A monotone event counter (relaxed atomics; safe from any thread).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An up/down level (pool queue depth); snapshots report the current
/// value, not a delta.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Raise the level by `n` (byte-accounted gauges like
    /// `cache.bytes_resident`).
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Lower the level by `n` (saturating: a racy double-subtract must
    /// not wrap a byte gauge to 2⁶⁴).
    #[inline]
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(
                cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-written float (training loss); stored as bits in an atomic.
pub struct FloatGauge(AtomicU64);

impl Default for FloatGauge {
    fn default() -> Self {
        FloatGauge(AtomicU64::new(0f64.to_bits()))
    }
}

impl FloatGauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Every metric family in the crate, as plain named fields — the whole
/// registry is static data, so probes compile to a `fetch_add` on a
/// fixed address.  Family and field names (the `family.field` strings
/// in snapshots) are assigned in [`Registry::snapshot`].
#[derive(Default)]
pub struct Registry {
    // scan kernels (exec/plan.rs): rows scanned per LUT precision, and
    // scan tasks executed
    pub scan_rows_f32: Counter,
    pub scan_rows_u16: Counter,
    pub scan_rows_u8: Counter,
    pub scan_rows_u4: Counter,
    pub scan_tasks: Counter,
    // SIMD dispatch (index/scan.rs): which kernel a scan call chose
    pub simd_dispatch_simd: Counter,
    pub simd_dispatch_scalar: Counter,
    // 1-bit sketch prefilter (index/scan.rs)
    pub prefilter_admitted: Counter,
    pub prefilter_rejected: Counter,
    // metadata predicate filter (index/filter.rs, exec/plan.rs): rows
    // skipped before selection, and bitmaps compiled per plan
    pub filter_rows_pruned: Counter,
    pub filter_bitmaps_built: Counter,
    // IVF routing (ivf/search.rs)
    pub ivf_lists_probed: Counter,
    pub ivf_residual_luts: Counter,
    // WAL (store/wal.rs)
    pub wal_appends: Counter,
    pub wal_commits: Counter,
    pub wal_fsync_us: LatencyHistogram,
    // compaction (index/segment.rs)
    pub compaction_runs: Counter,
    pub compaction_us: LatencyHistogram,
    // streaming reads (index/segment.rs): tombstone over-fetch and
    // segment fan-out
    pub stream_overfetch_rows: Counter,
    pub stream_segments_scanned: Counter,
    // hot-list cache (store/cache.rs) + block archive I/O
    // (store/blocks.rs) for the disk IVF tier (DESIGN.md §11)
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub cache_evictions: Counter,
    pub cache_bytes_resident: Gauge,
    pub blockio_read_us: LatencyHistogram,
    // worker pool (exec/pool.rs)
    pub exec_tasks: Counter,
    pub exec_queue_depth: Gauge,
    pub exec_task_us: LatencyHistogram,
    // training (quant/unq_native.rs)
    pub train_epochs: Counter,
    pub train_last_loss: FloatGauge,
    pub train_epoch_us: LatencyHistogram,
    // network front door (net/server.rs, rust/DESIGN.md §12):
    // connection lifecycle, request/response traffic, admission-control
    // rejections, framing failures, and wire bytes in each direction
    pub net_connections: Counter,
    pub net_requests: Counter,
    pub net_responses: Counter,
    pub net_errors: Counter,
    pub net_overloaded: Counter,
    pub net_quota_rejected: Counter,
    pub net_frame_errors: Counter,
    pub net_bytes_in: Counter,
    pub net_bytes_out: Counter,
    pub net_conns_open: Gauge,
    pub net_request_us: LatencyHistogram,
}

impl Registry {
    /// Point-in-time copy of every family, under its `family.field`
    /// name.  This list is the single source of metric names.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let c = |c: &Counter| c.get();
        MetricsSnapshot {
            counters: vec![
                ("scan.rows_f32".into(), c(&self.scan_rows_f32)),
                ("scan.rows_u16".into(), c(&self.scan_rows_u16)),
                ("scan.rows_u8".into(), c(&self.scan_rows_u8)),
                ("scan.rows_u4".into(), c(&self.scan_rows_u4)),
                ("scan.tasks".into(), c(&self.scan_tasks)),
                ("simd.dispatch_simd".into(), c(&self.simd_dispatch_simd)),
                ("simd.dispatch_scalar".into(),
                 c(&self.simd_dispatch_scalar)),
                ("prefilter.admitted".into(), c(&self.prefilter_admitted)),
                ("prefilter.rejected".into(), c(&self.prefilter_rejected)),
                ("filter.rows_pruned".into(), c(&self.filter_rows_pruned)),
                ("filter.bitmaps_built".into(),
                 c(&self.filter_bitmaps_built)),
                ("ivf.lists_probed".into(), c(&self.ivf_lists_probed)),
                ("ivf.residual_luts".into(), c(&self.ivf_residual_luts)),
                ("wal.appends".into(), c(&self.wal_appends)),
                ("wal.commits".into(), c(&self.wal_commits)),
                ("compaction.runs".into(), c(&self.compaction_runs)),
                ("stream.overfetch_rows".into(),
                 c(&self.stream_overfetch_rows)),
                ("stream.segments_scanned".into(),
                 c(&self.stream_segments_scanned)),
                ("cache.hits".into(), c(&self.cache_hits)),
                ("cache.misses".into(), c(&self.cache_misses)),
                ("cache.evictions".into(), c(&self.cache_evictions)),
                ("exec.tasks".into(), c(&self.exec_tasks)),
                ("train.epochs".into(), c(&self.train_epochs)),
                ("net.connections".into(), c(&self.net_connections)),
                ("net.requests".into(), c(&self.net_requests)),
                ("net.responses".into(), c(&self.net_responses)),
                ("net.errors".into(), c(&self.net_errors)),
                ("net.overloaded".into(), c(&self.net_overloaded)),
                ("net.quota_rejected".into(),
                 c(&self.net_quota_rejected)),
                ("net.frame_errors".into(), c(&self.net_frame_errors)),
                ("net.bytes_in".into(), c(&self.net_bytes_in)),
                ("net.bytes_out".into(), c(&self.net_bytes_out)),
            ],
            gauges: vec![
                ("cache.bytes_resident".into(),
                 self.cache_bytes_resident.get() as f64),
                ("exec.queue_depth".into(),
                 self.exec_queue_depth.get() as f64),
                ("train.last_loss".into(), self.train_last_loss.get()),
                ("net.conns_open".into(),
                 self.net_conns_open.get() as f64),
            ],
            hists: vec![
                ("wal.fsync_us".into(), self.wal_fsync_us.snapshot()),
                ("compaction.duration_us".into(),
                 self.compaction_us.snapshot()),
                ("blockio.read_us".into(),
                 self.blockio_read_us.snapshot()),
                ("exec.task_us".into(), self.exec_task_us.snapshot()),
                ("train.epoch_us".into(), self.train_epoch_us.snapshot()),
                ("net.request_us".into(), self.net_request_us.snapshot()),
            ],
        }
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry every instrumentation site records into.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::default)
}

/// A named, plain-data copy of the registry: what `unq stats` prints,
/// what benches bracket ([`MetricsSnapshot::delta`]), and what rides
/// in BENCH_*.json rows.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

impl MetricsSnapshot {
    /// Counter value by name (0 when absent — a snapshot from an older
    /// binary simply reads as zero).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0.0, |(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Everything recorded since `earlier`: counters and histogram
    /// buckets subtract (saturating); gauges keep the later value
    /// (levels, not rates).  The bench brackets: snapshot → run →
    /// snapshot → `delta`.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .iter()
                .map(|(n, v)| {
                    (n.clone(), v.saturating_sub(earlier.counter(n)))
                })
                .collect(),
            gauges: self.gauges.clone(),
            hists: self
                .hists
                .iter()
                .map(|(n, h)| {
                    let e = earlier.hist(n).cloned()
                        .unwrap_or_else(HistSnapshot::empty);
                    (n.clone(), h.delta(&e))
                })
                .collect(),
        }
    }

    /// JSON export.  Histograms carry their raw fields plus derived
    /// p50/p95/p99 for human readers; [`MetricsSnapshot::from_json`]
    /// ignores the derived keys, so the struct round-trips exactly
    /// (counters are u64 but serialize through f64 — exact below 2⁵³,
    /// far beyond any real count here).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("counters",
             Json::Obj(self.counters.iter()
                 .map(|(n, v)| (n.clone(), Json::Num(*v as f64)))
                 .collect())),
            ("gauges",
             Json::Obj(self.gauges.iter()
                 .map(|(n, v)| (n.clone(), Json::Num(*v)))
                 .collect())),
            ("hists",
             Json::Obj(self.hists.iter()
                 .map(|(n, h)| {
                     (n.clone(), Json::obj(vec![
                         ("count", Json::Num(h.count as f64)),
                         ("sum_us", Json::Num(h.sum_us as f64)),
                         ("max_us", Json::Num(h.max_us as f64)),
                         ("p50_us", Json::Num(h.quantile_us(0.5) as f64)),
                         ("p95_us", Json::Num(h.quantile_us(0.95) as f64)),
                         ("p99_us", Json::Num(h.quantile_us(0.99) as f64)),
                         ("buckets", Json::Arr(
                             h.buckets.iter()
                                 .map(|&b| Json::Num(b as f64))
                                 .collect())),
                     ]))
                 })
                 .collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        let obj_pairs = |key: &str| -> Result<Vec<(String, Json)>> {
            match j.get(key) {
                Some(Json::Obj(pairs)) => Ok(pairs.clone()),
                _ => bail!("snapshot missing object field {key:?}"),
            }
        };
        let counters = obj_pairs("counters")?
            .into_iter()
            .map(|(n, v)| match v.as_f64() {
                Some(f) => Ok((n, f as u64)),
                None => bail!("counter {n:?} is not a number"),
            })
            .collect::<Result<Vec<_>>>()?;
        let gauges = obj_pairs("gauges")?
            .into_iter()
            .map(|(n, v)| match v.as_f64() {
                Some(f) => Ok((n, f)),
                None => bail!("gauge {n:?} is not a number"),
            })
            .collect::<Result<Vec<_>>>()?;
        let hists = obj_pairs("hists")?
            .into_iter()
            .map(|(n, v)| {
                let mut h = HistSnapshot::empty();
                let bs = v.get("buckets").and_then(Json::as_arr)
                    .ok_or_else(|| {
                        anyhow::anyhow!("hist {n:?} missing buckets")
                    })?;
                if bs.len() != hist::BUCKETS {
                    bail!("hist {n:?} has {} buckets, want {}",
                          bs.len(), hist::BUCKETS);
                }
                for (i, b) in bs.iter().enumerate() {
                    h.buckets[i] = b.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("hist {n:?} bucket {i} not a number")
                    })? as u64;
                }
                h.count = v.req_usize("count")? as u64;
                h.sum_us = v.req_usize("sum_us")? as u64;
                h.max_us = v.req_usize("max_us")? as u64;
                Ok((n, h))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(MetricsSnapshot { counters, gauges, hists })
    }

    /// Check this snapshot against a committed schema
    /// (`BENCH_obs.schema.json`): every listed counter/gauge/hist name
    /// must be present; names listed under `"nonzero"` must also have a
    /// count > 0.  Returns every violation, so CI prints the full list.
    pub fn check_schema(&self, schema: &Json) -> Vec<String> {
        let mut errs = Vec::new();
        let names = |key: &str| -> Vec<String> {
            schema.get(key).and_then(Json::as_arr).map_or(Vec::new(), |a| {
                a.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
        };
        for n in names("counters") {
            if !self.counters.iter().any(|(c, _)| *c == n) {
                errs.push(format!("missing counter {n:?}"));
            }
        }
        for n in names("gauges") {
            if !self.gauges.iter().any(|(g, _)| *g == n) {
                errs.push(format!("missing gauge {n:?}"));
            }
        }
        for n in names("hists") {
            if self.hist(&n).is_none() {
                errs.push(format!("missing hist {n:?}"));
            }
        }
        for n in names("nonzero") {
            let ok = self.counters.iter().any(|(c, v)| *c == n && *v > 0)
                || self.hist(&n).is_some_and(|h| h.count > 0);
            if !ok {
                errs.push(format!("{n:?} must be non-zero"));
            }
        }
        errs
    }

    /// The human summary `unq stats` and the `unq ingest` footer print:
    /// one line per non-zero family member.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for (n, v) in &self.counters {
            if *v > 0 {
                out.push_str(&format!("{n:<26} {v}\n"));
            }
        }
        // derived: hot-list cache hit rate, the one ratio the disk tier
        // is tuned by (DESIGN.md §11)
        let (h, m) = (self.counter("cache.hits"),
                      self.counter("cache.misses"));
        if h + m > 0 {
            out.push_str(&format!(
                "{:<26} {:.1}% ({h}/{})\n",
                "cache.hit_rate",
                100.0 * h as f64 / (h + m) as f64,
                h + m
            ));
        }
        for (n, v) in &self.gauges {
            if *v != 0.0 {
                out.push_str(&format!("{n:<26} {v:.4}\n"));
            }
        }
        for (n, h) in &self.hists {
            if h.count > 0 {
                out.push_str(&format!(
                    "{n:<26} n={} mean={:.1}µs p50={}µs p99={}µs max={}µs\n",
                    h.count,
                    h.mean_us(),
                    h.quantile_us(0.5),
                    h.quantile_us(0.99),
                    h.max_us
                ));
            }
        }
        if out.is_empty() {
            out.push_str("(no metrics recorded)\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: tests touching `global()` share it with every other test in
    // the binary (cargo test is multi-threaded), so they assert
    // monotone / ≥ facts only; exact-value assertions use local
    // registries or per-trace counters.

    #[test]
    fn counter_gauge_float_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(7);
        assert_eq!(g.get(), 7);
        let f = FloatGauge::default();
        assert_eq!(f.get(), 0.0);
        f.set(1.25);
        assert_eq!(f.get(), 1.25);
    }

    #[test]
    fn global_registry_is_shared_and_monotone() {
        let before = global().scan_tasks.get();
        global().scan_tasks.inc();
        assert!(global().scan_tasks.get() >= before + 1);
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let reg = Registry::default();
        reg.scan_rows_u8.add(12345);
        reg.prefilter_admitted.add(40);
        reg.prefilter_rejected.add(60);
        reg.exec_queue_depth.set(3);
        reg.train_last_loss.set(0.125);
        reg.wal_fsync_us.record(180);
        reg.wal_fsync_us.record(2500);
        let snap = reg.snapshot();
        let text = snap.to_json().render_pretty();
        let back = MetricsSnapshot::from_json(
            &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.counter("scan.rows_u8"), 12345);
        assert_eq!(back.gauge("train.last_loss"), 0.125);
        assert_eq!(back.hist("wal.fsync_us").unwrap().count, 2);
    }

    #[test]
    fn delta_subtracts_counters_and_hists() {
        let reg = Registry::default();
        reg.ivf_lists_probed.add(10);
        reg.exec_task_us.record(50);
        let before = reg.snapshot();
        reg.ivf_lists_probed.add(7);
        reg.exec_task_us.record(90);
        reg.exec_queue_depth.set(2);
        let d = reg.snapshot().delta(&before);
        assert_eq!(d.counter("ivf.lists_probed"), 7);
        assert_eq!(d.hist("exec.task_us").unwrap().count, 1);
        // gauges are levels: delta reports the later value
        assert_eq!(d.gauge("exec.queue_depth"), 2.0);
        // untouched families are all-zero in the delta
        assert_eq!(d.counter("wal.appends"), 0);
    }

    #[test]
    fn schema_check_reports_missing_and_zero() {
        let reg = Registry::default();
        reg.scan_rows_f32.add(1);
        let snap = reg.snapshot();
        let schema = Json::parse(
            r#"{"counters": ["scan.rows_f32", "no.such"],
                "hists": ["wal.fsync_us"],
                "nonzero": ["scan.rows_f32", "wal.appends"]}"#,
        )
        .unwrap();
        let errs = snap.check_schema(&schema);
        assert_eq!(errs.len(), 2, "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("no.such")));
        assert!(errs.iter().any(|e| e.contains("wal.appends")));
        // and a clean schema passes
        let ok = Json::parse(
            r#"{"counters": ["scan.rows_f32"],
                "nonzero": ["scan.rows_f32"]}"#,
        )
        .unwrap();
        assert!(snap.check_schema(&ok).is_empty());
    }

    #[test]
    fn render_human_lists_only_nonzero() {
        let reg = Registry::default();
        let empty = reg.snapshot().render_human();
        assert!(empty.contains("no metrics recorded"));
        reg.wal_appends.add(3);
        reg.compaction_us.record(1500);
        let text = reg.snapshot().render_human();
        assert!(text.contains("wal.appends"));
        assert!(text.contains("compaction.duration_us"));
        assert!(!text.contains("scan.rows_f32"));
    }

    #[test]
    fn gauge_add_sub_saturates_at_zero() {
        let g = Gauge::default();
        g.add(100);
        g.sub(30);
        assert_eq!(g.get(), 70);
        // never wraps: subtracting past zero clamps
        g.sub(1000);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn render_human_derives_cache_hit_rate() {
        let reg = Registry::default();
        // no cache traffic → no hit-rate line
        assert!(!reg.snapshot().render_human()
            .contains("cache.hit_rate"));
        reg.cache_hits.add(3);
        reg.cache_misses.add(1);
        reg.cache_bytes_resident.add(4096);
        reg.blockio_read_us.record(120);
        let text = reg.snapshot().render_human();
        assert!(text.contains("cache.hits"));
        assert!(text.contains("cache.hit_rate"));
        assert!(text.contains("75.0% (3/4)"), "{text}");
        assert!(text.contains("cache.bytes_resident"));
        assert!(text.contains("blockio.read_us"));
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(MetricsSnapshot::from_json(&Json::Null).is_err());
        let bad = Json::parse(
            r#"{"counters": {"a": "x"}, "gauges": {}, "hists": {}}"#,
        )
        .unwrap();
        assert!(MetricsSnapshot::from_json(&bad).is_err());
        let bad_hist = Json::parse(
            r#"{"counters": {}, "gauges": {},
                "hists": {"h": {"count": 1, "sum_us": 1, "max_us": 1,
                                "buckets": [1, 2]}}}"#,
        )
        .unwrap();
        assert!(MetricsSnapshot::from_json(&bad_hist).is_err());
    }
}
