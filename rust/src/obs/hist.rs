//! Log-bucketed latency histogram: the crate-wide timing substrate
//! (generalized out of `coordinator/metrics.rs`, which now re-exports
//! it — rust/DESIGN.md §10).
//!
//! 64 buckets at true √2 spacing cover 1 µs … 2³² µs (~71 min);
//! recording is a single relaxed `fetch_add` per field, safe from any
//! thread.  Bucket `i` holds values in `[lower(i), lower(i+1))` with
//! `lower(2·k) = 2^k` and `lower(2·k + 1) = ⌈√2·2^k⌉` — the half-bucket
//! boundary is exact (`us ≥ √2·2^k  ⇔  us² ≥ 2^(2k+1)`, compared in
//! u128), fixing the old `coordinator/metrics.rs` condition that tested
//! the top bit of `us` (vacuously true) and placed the boundary at
//! `1.5·2^k`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: two per power of two over 32 octaves.
pub const BUCKETS: usize = 64;

/// Log-bucketed latency histogram over microseconds.
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Smallest value of bucket `i` (the bucket covers
/// `[lower(i), lower(i+1))`; the last bucket is open-ended).
#[inline]
pub(crate) fn bucket_lower(i: usize) -> u64 {
    let log2 = i / 2;
    if i % 2 == 0 {
        1u64 << log2
    } else {
        // ⌈√2 · 2^log2⌉ = ⌊√(2^(2·log2+1))⌋ + 1: 2^(odd) is never a
        // perfect square, so floor + 1 is exactly the ceiling
        (1u128 << (2 * log2 + 1)).isqrt() as u64 + 1
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Bucket index of a microsecond value: `2·⌊log2 us⌋`, plus one when
    /// the value reaches the √2 midpoint of its octave.  The midpoint
    /// test squares into u128, so it is exact for the full u64 range.
    #[inline]
    pub(crate) fn bucket_of(us: u64) -> usize {
        let us = us.max(1);
        let log2 = 63 - us.leading_zeros() as usize;
        let half =
            ((us as u128) * (us as u128) >= 1u128 << (2 * log2 + 1)) as usize;
        (2 * log2 + half).min(BUCKETS - 1)
    }

    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile: the **upper bound** of the bucket containing
    /// the q-th ranked sample (the last bucket reports the observed max),
    /// so the true quantile is always ≤ the reported value and within
    /// one √2 bucket of it.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let snap = self.snapshot();
        snap.quantile_us(q)
    }

    /// A point-in-time copy of every bucket (relaxed loads; concurrent
    /// recording may tear *across* fields, never within one).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            count: self.count(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us(),
        }
    }
}

/// Plain-data histogram snapshot: what [`LatencyHistogram::snapshot`]
/// returns, what `MetricsSnapshot` serializes, and what bench brackets
/// subtract ([`HistSnapshot::delta`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { buckets: [0; BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Same quantile rule as the live histogram, computed from the
    /// snapshot's buckets.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                if i + 1 >= BUCKETS {
                    break;
                }
                // upper bound of bucket i, capped by the observed max
                return (bucket_lower(i + 1) - 1).min(self.max_us);
            }
        }
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Counts recorded since `earlier` (bucket-wise saturating
    /// subtraction; `max_us` keeps the later value — a maximum cannot be
    /// un-observed, so deltas report the lifetime max).
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
            count: self.count.saturating_sub(earlier.count),
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            max_us: self.max_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::SplitMix64};

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::new();
        for us in [10, 20, 30, 40] {
            h.record(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 25.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 40);
    }

    #[test]
    fn bucket_boundaries_are_exact_sqrt2() {
        // exhaustive boundary check over every octave that fits u64
        // arithmetic cleanly: for each k, 2^k opens bucket 2k, and the
        // first integer ≥ √2·2^k opens bucket 2k+1 (the value one below
        // it still lands in bucket 2k)
        for k in 0..31usize {
            let base = 1u64 << k;
            assert_eq!(LatencyHistogram::bucket_of(base), 2 * k,
                       "2^{k} must open its octave");
            assert_eq!(LatencyHistogram::bucket_of(2 * base - 1), 2 * k + 1,
                       "top of octave {k}");
            let mid = bucket_lower(2 * k + 1);
            assert_eq!(LatencyHistogram::bucket_of(mid), 2 * k + 1,
                       "⌈√2·2^{k}⌉ = {mid} must open the half bucket");
            if mid > base {
                assert_eq!(LatencyHistogram::bucket_of(mid - 1), 2 * k,
                           "{} must stay in the low half of octave {k}",
                           mid - 1);
            }
            // the midpoint really is the √2 boundary: mid² ≥ 2^(2k+1)
            // and (mid−1)² < 2^(2k+1)
            let sq = 1u128 << (2 * k + 1);
            assert!((mid as u128) * (mid as u128) >= sq);
            assert!(((mid - 1) as u128) * ((mid - 1) as u128) < sq);
        }
        // the specific values the old half-bucket condition mis-bucketed
        assert_eq!(LatencyHistogram::bucket_of(2), 2);
        assert_eq!(LatencyHistogram::bucket_of(3), 3);
        // saturation: everything ≥ 2^32 shares the last bucket
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(LatencyHistogram::bucket_of(1 << 40), BUCKETS - 1);
    }

    #[test]
    fn bucket_of_is_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 9, 17, 100, 1000, 10_000, 1 << 40] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn quantiles_monotone() {
        let h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(us);
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // bucketed approximation: p50 of uniform 1..1000 is within [256,1024]
        assert!((256..=1024).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn prop_quantile_within_one_bucket_of_exact() {
        // the percentile-bound contract: for random samples and random
        // q, the reported quantile's bucket is within one √2 bucket of
        // the exact sample quantile's bucket (and never below it —
        // the upper-bound rule over-reports, never under-reports)
        prop::forall_ok(
            20_26,
            40,
            |r: &mut SplitMix64| {
                let n = 1 + r.below(400);
                let q = [0.5, 0.9, 0.95, 0.99, 1.0][r.below(5)];
                (n, q, r.next_u64())
            },
            |&(n, q, seed)| {
                let mut r = SplitMix64::new(seed);
                let h = LatencyHistogram::new();
                let mut vals = Vec::with_capacity(n);
                for _ in 0..n {
                    // spread over many octaves, including sub-µs clamps
                    let v = r.next_u64() >> (r.below(60) as u32);
                    h.record(v);
                    vals.push(v.max(1));
                }
                vals.sort_unstable();
                let rank = ((q * n as f64).ceil().max(1.0) as usize).min(n);
                let exact = vals[rank - 1];
                let got = h.quantile_us(q);
                let (be, bg) = (LatencyHistogram::bucket_of(exact),
                                LatencyHistogram::bucket_of(got));
                if bg >= be && bg <= be + 1 && got >= exact {
                    Ok(())
                } else {
                    Err(format!(
                        "q={q} exact={exact} (bucket {be}) \
                         got={got} (bucket {bg})"
                    ))
                }
            },
        );
    }

    #[test]
    fn snapshot_delta_subtracts_bucketwise() {
        let h = LatencyHistogram::new();
        h.record(10);
        h.record(100);
        let before = h.snapshot();
        h.record(10);
        h.record(1000);
        let d = h.snapshot().delta(&before);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum_us, 1010);
        assert_eq!(d.buckets[LatencyHistogram::bucket_of(10)], 1);
        assert_eq!(d.buckets[LatencyHistogram::bucket_of(1000)], 1);
        assert_eq!(d.buckets[LatencyHistogram::bucket_of(100)], 0);
        assert_eq!(d.max_us, 1000);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.snapshot(), HistSnapshot::empty());
    }
}
