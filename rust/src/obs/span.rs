//! Hierarchical span tracing: per-query (per-batch) span trees that
//! render as the `unq search --explain` report (rust/DESIGN.md §10).
//!
//! Design constraints, in order:
//!
//! 1. **Zero perturbation** — tracing is a read-only side channel.  It
//!    never changes task order, shard decomposition, or scores, so
//!    results are bit-identical with tracing on or off (property-pinned
//!    in `exec::plan`).
//! 2. **Single branch when disabled** — [`enter`] first loads one global
//!    relaxed atomic (the count of live [`Trace`] collectors); when zero
//!    it returns an inert guard without touching the thread-local stack,
//!    allocating, or reading the clock.  `tests/obs_overhead.rs` pins
//!    the no-allocation half of that contract with a counting allocator.
//! 3. **Pool-correct parenting** — spans cross `exec` worker threads via
//!    an explicit [`TraceHandle`]: the planner captures the current
//!    (trace, span) pair once per plan and each pool job installs it for
//!    the job's duration, so concurrent traces on one shared pool never
//!    leak spans into each other.  Guards close on unwind (`Drop`), so a
//!    panicking task still records its span.
//!
//! Lifecycle: [`Trace::begin`] creates the collector plus the root span
//! and installs it on the calling thread; [`enter`] (or the
//! `crate::span!` macro) opens a child of the innermost open span on
//! this thread; dropping the root guard closes the tree, after which
//! [`Trace::render`] / [`Trace::to_json`] produce the EXPLAIN report.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Count of live [`Trace`] collectors — the global master gate every
/// [`enter`] checks first.  Zero (the overwhelmingly common case) makes
/// span guards a load + branch.
static LIVE_TRACES: AtomicU64 = AtomicU64::new(0);

/// Is any trace alive anywhere in the process?  (The cheap pre-check;
/// a true result still requires a trace installed on *this* thread for
/// spans to attach.)
#[inline]
pub fn tracing_active() -> bool {
    LIVE_TRACES.load(Ordering::Relaxed) != 0
}

thread_local! {
    /// Innermost open span per thread: (collector, span id) pairs pushed
    /// by span guards and [`TraceHandle::install`], popped strictly LIFO
    /// on drop (unwind included).  Const-init: no allocation until a
    /// trace actually reaches this thread.
    static STACK: RefCell<Vec<(Arc<TraceInner>, u32)>> =
        const { RefCell::new(Vec::new()) };
}

/// One closed span, as collected.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub id: u32,
    /// Parent span id (the root's parent is itself).
    pub parent: u32,
    pub label: &'static str,
    /// Wall time between guard creation and drop.
    pub dur_ns: u64,
    /// Additive per-span payload (rows scanned, lists probed, …).
    pub rows: u64,
}

struct TraceInner {
    epoch: Instant,
    next_id: AtomicU32,
    /// Closed spans, pushed on guard drop (a short lock only while
    /// tracing is on; the disabled path never reaches here).
    spans: Mutex<Vec<SpanRecord>>,
}

impl TraceInner {
    fn close(&self, rec: SpanRecord) {
        self.spans.lock().expect("span sink poisoned").push(rec);
    }
}

/// A span-tree collector for one query (or one flushed batch).
pub struct Trace {
    inner: Arc<TraceInner>,
}

impl Drop for Trace {
    fn drop(&mut self) {
        LIVE_TRACES.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Trace {
    /// Create a collector, open its root span, and install both on the
    /// calling thread.  Drop the guard to close the tree, then render.
    pub fn begin(label: &'static str) -> (Trace, SpanGuard) {
        LIVE_TRACES.fetch_add(1, Ordering::Relaxed);
        let inner = Arc::new(TraceInner {
            epoch: Instant::now(),
            next_id: AtomicU32::new(1),
            spans: Mutex::new(Vec::new()),
        });
        let trace = Trace { inner: inner.clone() };
        STACK.with(|s| s.borrow_mut().push((inner.clone(), 0)));
        let guard = SpanGuard {
            live: Some(LiveSpan {
                trace: inner,
                id: 0,
                parent: 0,
                label,
                start: Instant::now(),
                rows: 0,
            }),
        };
        (trace, guard)
    }

    /// A sendable (trace, span) pair for parenting spans opened on other
    /// threads under the *current* innermost span of this thread.
    /// `None` when this thread has no open span (tracing off, or the
    /// calling code isn't under a trace) — plan code forwards the
    /// `None` for free.
    pub fn current_handle() -> Option<TraceHandle> {
        if !tracing_active() {
            return None;
        }
        STACK.with(|s| {
            s.borrow().last().map(|(t, id)| TraceHandle {
                trace: t.clone(),
                span: *id,
            })
        })
    }

    /// Number of closed spans so far (tests).
    pub fn len(&self) -> usize {
        self.inner.spans.lock().expect("span sink poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Closed spans, in close order (tests + custom reports).
    pub fn records(&self) -> Vec<SpanRecord> {
        self.inner.spans.lock().expect("span sink poisoned").clone()
    }

    /// Sum of `rows` over closed spans with this label (tests pin scan
    /// row accounting through this).
    pub fn rows(&self, label: &str) -> u64 {
        self.records()
            .iter()
            .filter(|r| r.label == label)
            .map(|r| r.rows)
            .sum()
    }

    /// The EXPLAIN tree: one line per (parent-path, label) group, with
    /// call count, summed wall time, summed **self** time (wall minus
    /// child spans — self times over the whole tree sum exactly to the
    /// root's wall time), and summed rows.  Spans sharing a label under
    /// one parent aggregate into a single line (a 16-task scan prints
    /// once), keeping the report readable at any fan-out.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for line in self.explain_lines() {
            let indent = "  ".repeat(line.depth);
            let mut s = format!(
                "{indent}{} ({}x) total {} self {}",
                line.label,
                line.calls,
                fmt_ns(line.dur_ns),
                fmt_ns(line.self_ns)
            );
            if line.rows > 0 {
                s.push_str(&format!(" rows {}", line.rows));
            }
            out.push_str(&s);
            out.push('\n');
        }
        out
    }

    /// The EXPLAIN tree as JSON (the coordinator's `trace` payload and
    /// the CLI's `--json` shape): an array of
    /// `{label, depth, calls, dur_us, self_us, rows}` rows in tree
    /// order.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.explain_lines()
                .into_iter()
                .map(|l| {
                    Json::obj(vec![
                        ("label", Json::Str(l.label.to_string())),
                        ("depth", Json::Num(l.depth as f64)),
                        ("calls", Json::Num(l.calls as f64)),
                        ("dur_us", Json::Num(l.dur_ns as f64 / 1000.0)),
                        ("self_us", Json::Num(l.self_ns as f64 / 1000.0)),
                        ("rows", Json::Num(l.rows as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// Aggregate the raw span list into depth-first display lines.
    fn explain_lines(&self) -> Vec<ExplainLine> {
        let records = self.records();
        // children's wall time per parent id, for self-time subtraction
        let mut child_ns: Vec<u64> = vec![0; records.len().max(1)];
        let mut by_id: Vec<Option<&SpanRecord>> =
            vec![None; records.len().max(1)];
        for r in &records {
            if (r.id as usize) < by_id.len() {
                by_id[r.id as usize] = Some(r);
            }
        }
        for r in &records {
            if r.id != r.parent && (r.parent as usize) < child_ns.len() {
                child_ns[r.parent as usize] += r.dur_ns;
            }
        }
        // group by (parent, label), keyed for stable tree placement
        let mut lines: Vec<ExplainLine> = Vec::new();
        fn walk(parent: u32, depth: usize, records: &[SpanRecord],
                child_ns: &[u64], lines: &mut Vec<ExplainLine>) {
            let mut seen: Vec<&'static str> = Vec::new();
            for r in records {
                if r.parent != parent || r.id == r.parent {
                    continue;
                }
                if seen.contains(&r.label) {
                    continue;
                }
                seen.push(r.label);
                let group: Vec<&SpanRecord> = records
                    .iter()
                    .filter(|c| {
                        c.parent == parent && c.id != c.parent
                            && c.label == r.label
                    })
                    .collect();
                let dur: u64 = group.iter().map(|c| c.dur_ns).sum();
                let selfd: u64 = group
                    .iter()
                    .map(|c| {
                        c.dur_ns.saturating_sub(
                            child_ns.get(c.id as usize).copied().unwrap_or(0))
                    })
                    .sum();
                lines.push(ExplainLine {
                    label: r.label,
                    depth,
                    calls: group.len(),
                    dur_ns: dur,
                    self_ns: selfd,
                    rows: group.iter().map(|c| c.rows).sum(),
                });
                for c in group {
                    walk(c.id, depth + 1, records, child_ns, lines);
                }
            }
        }
        if let Some(root) =
            records.iter().find(|r| r.id == r.parent)
        {
            lines.push(ExplainLine {
                label: root.label,
                depth: 0,
                calls: 1,
                dur_ns: root.dur_ns,
                self_ns: root.dur_ns.saturating_sub(
                    child_ns.get(0).copied().unwrap_or(0)),
                rows: root.rows,
            });
            walk(root.id, 1, &records, &child_ns, &mut lines);
        }
        lines
    }
}

/// One aggregated EXPLAIN display line.
pub struct ExplainLine {
    pub label: &'static str,
    pub depth: usize,
    pub calls: usize,
    pub dur_ns: u64,
    pub self_ns: u64,
    pub rows: u64,
}

fn fmt_ns(ns: u64) -> String {
    format!("{:.1}µs", ns as f64 / 1000.0)
}

/// A sendable (trace, parent span) pair: what plan code captures once
/// and clones into each pool job.
#[derive(Clone)]
pub struct TraceHandle {
    trace: Arc<TraceInner>,
    span: u32,
}

impl TraceHandle {
    /// Make this handle the innermost open span of the current thread
    /// until the returned guard drops (strict LIFO, unwind-safe).
    pub fn install(&self) -> InstallGuard {
        STACK.with(|s| {
            s.borrow_mut().push((self.trace.clone(), self.span))
        });
        InstallGuard
    }
}

/// Pops the thread's span stack on drop (see [`TraceHandle::install`]).
pub struct InstallGuard;

impl Drop for InstallGuard {
    fn drop(&mut self) {
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

struct LiveSpan {
    trace: Arc<TraceInner>,
    id: u32,
    parent: u32,
    label: &'static str,
    start: Instant,
    rows: u64,
}

/// RAII span: records `(label, wall time, rows)` under the innermost
/// open span on drop.  Inert (`live: None`) when tracing is off — the
/// single-branch disabled path.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

impl SpanGuard {
    /// Is this guard actually recording?  (Tests.)
    pub fn is_active(&self) -> bool {
        self.live.is_some()
    }

    /// Add to the span's additive payload (rows scanned, lists probed —
    /// whatever the stage counts).  Free when inert.
    #[inline]
    pub fn add_rows(&mut self, n: u64) {
        if let Some(l) = &mut self.live {
            l.rows += n;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(live) = self.live.take() else { return };
        let dur_ns = live.start.elapsed().as_nanos() as u64;
        // pop this span off the thread stack (strict LIFO: nested guards
        // drop before their parents, unwind included)
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
        let _ = live.trace.epoch; // reserved for future absolute timestamps
        live.trace.close(SpanRecord {
            id: live.id,
            parent: live.parent,
            label: live.label,
            dur_ns,
            rows: live.rows,
        });
    }
}

/// Open a span under the innermost open span of this thread.  One
/// relaxed load + branch when no trace is live anywhere; inert (but
/// still cheap) when traces exist only on other threads.
#[inline]
pub fn enter(label: &'static str) -> SpanGuard {
    if !tracing_active() {
        return SpanGuard { live: None };
    }
    enter_slow(label)
}

#[inline(never)]
fn enter_slow(label: &'static str) -> SpanGuard {
    STACK.with(|s| {
        let mut stack = s.borrow_mut();
        let Some((trace, parent)) = stack.last().cloned() else {
            return SpanGuard { live: None };
        };
        let id = trace.next_id.fetch_add(1, Ordering::Relaxed);
        stack.push((trace.clone(), id));
        SpanGuard {
            live: Some(LiveSpan {
                trace,
                id,
                parent,
                label,
                start: Instant::now(),
                rows: 0,
            }),
        }
    })
}

/// Open a named span under the innermost open span of the current
/// thread — see [`crate::obs::span::enter`].  Expands to a single
/// function call so the disabled path stays one load + branch.
#[macro_export]
macro_rules! span {
    ($label:expr) => {
        $crate::obs::span::enter($label)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // no trace on this thread (other tests' traces may exist on
        // their own threads — the guard must still be inert here
        // because nothing is installed on THIS thread's stack)
        let g = enter("nothing");
        assert!(!g.is_active());
        drop(g);
    }

    #[test]
    fn nested_spans_parent_correctly() {
        let (trace, root) = Trace::begin("root");
        {
            let mut a = enter("a");
            a.add_rows(10);
            {
                let b = enter("b");
                assert!(b.is_active());
            }
        }
        {
            let mut a2 = enter("a");
            a2.add_rows(5);
        }
        drop(root);
        let recs = trace.records();
        assert_eq!(recs.len(), 4, "b, a, a(2nd), root");
        let root_rec = recs.iter().find(|r| r.label == "root").unwrap();
        assert_eq!(root_rec.id, root_rec.parent, "root parents itself");
        for a in recs.iter().filter(|r| r.label == "a") {
            assert_eq!(a.parent, root_rec.id, "a under root");
        }
        let b = recs.iter().find(|r| r.label == "b").unwrap();
        let a_ids: Vec<u32> = recs
            .iter()
            .filter(|r| r.label == "a")
            .map(|r| r.id)
            .collect();
        assert!(a_ids.contains(&b.parent), "b under an a span");
        assert_eq!(trace.rows("a"), 15);
    }

    #[test]
    fn handle_reparents_across_threads() {
        let (trace, mut root) = Trace::begin("root");
        root.add_rows(1);
        let handle = {
            let _scan = enter("scan");
            Trace::current_handle().expect("under a trace")
        };
        // "scan" is closed; spans opened through the handle must still
        // parent to it, from another thread
        let t = std::thread::spawn(move || {
            let _install = handle.install();
            let mut task = enter("task");
            task.add_rows(42);
        });
        t.join().unwrap();
        drop(root);
        let recs = trace.records();
        let scan = recs.iter().find(|r| r.label == "scan").unwrap();
        let task = recs.iter().find(|r| r.label == "task").unwrap();
        assert_eq!(task.parent, scan.id);
        assert_eq!(trace.rows("task"), 42);
    }

    #[test]
    fn concurrent_traces_do_not_cross_leak() {
        // two traces on two threads, spans interleaved: every span must
        // land in its own thread's trace
        let mk = || {
            std::thread::spawn(|| {
                let (trace, root) = Trace::begin("root");
                for _ in 0..50 {
                    let mut s = enter("work");
                    s.add_rows(1);
                }
                drop(root);
                trace.rows("work")
            })
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.join().unwrap(), 50);
        assert_eq!(b.join().unwrap(), 50);
    }

    #[test]
    fn self_times_sum_to_root_duration() {
        let (trace, root) = Trace::begin("root");
        {
            let _a = enter("a");
            let _b = enter("b");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        {
            let _c = enter("c");
        }
        drop(root);
        let lines = trace.explain_lines();
        let root_dur = lines[0].dur_ns;
        let self_sum: u64 = lines.iter().map(|l| l.self_ns).sum();
        // exact by construction (telescoping sum), ±1% per acceptance
        let tol = root_dur / 100 + 1;
        assert!(self_sum.abs_diff(root_dur) <= tol,
                "self {self_sum} vs root {root_dur}");
        let rendered = trace.render();
        assert!(rendered.contains("root"));
        assert!(rendered.contains("a"));
    }

    #[test]
    fn panic_on_worker_still_closes_span() {
        let (trace, root) = Trace::begin("root");
        let handle = Trace::current_handle().unwrap();
        let t = std::thread::spawn(move || {
            let _install = handle.install();
            let _span = enter("doomed");
            panic!("task boom");
        });
        assert!(t.join().is_err(), "the task panicked");
        drop(root);
        let recs = trace.records();
        assert!(recs.iter().any(|r| r.label == "doomed"),
                "unwind must close the span");
    }

    #[test]
    fn render_and_json_shapes() {
        let (trace, root) = Trace::begin("search");
        {
            let mut s = enter("scan");
            s.add_rows(1000);
        }
        {
            let mut s = enter("scan");
            s.add_rows(500);
        }
        drop(root);
        let txt = trace.render();
        assert!(txt.contains("scan (2x)"), "aggregated line: {txt}");
        assert!(txt.contains("rows 1500"), "summed rows: {txt}");
        let j = trace.to_json();
        let arr = j.as_arr().expect("array of lines");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("label").and_then(|l| l.as_str()),
                   Some("search"));
        assert_eq!(arr[1].get("rows").and_then(|r| r.as_f64()),
                   Some(1500.0));
    }
}
