//! Experiment harness: train-or-load quantizers, build-or-load indexes,
//! run the two-stage search over the query set, compute Recall@k.
//!
//! This is the shared engine behind `unq tables`, the per-table benches
//! and the examples.  Heavy artifacts (trained baselines, encoded
//! databases) are cached under `runs/` keyed by (dataset, method, bytes,
//! base size), so regenerating a table re-uses everything that already
//! exists.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context};

use crate::config::{AppConfig, QuantizerKind, ScanPrecision, SearchConfig};
use crate::data::{self, Dataset};
use crate::exec::Executor;
use crate::gt::GroundTruth;
use crate::index::{CompressedIndex, SearchEngine};
use crate::ivf::disk::DiskIvfIndex;
use crate::ivf::{CoarseQuantizer, IvfIndex};
use crate::quant::{additive::Additive, lattice, lsq, opq::Opq, pq::Pq,
                   unq::UnqQuantizer, unq_native::NativeUnq, Quantizer};
use crate::runtime::UnqRuntime;
use crate::store::Store;
use crate::Result;

use super::{recall, Recall};

/// Everything needed to evaluate one (dataset, method, bytes) cell.
pub struct Experiment {
    pub cfg: AppConfig,
    pub splits: data::Splits,
    pub gt: GroundTruth,
    /// kept alive for UNQ (owns the runtime thread)
    pub runtime: Option<UnqRuntime>,
    pub quant: Box<dyn Quantizer>,
    pub index: CompressedIndex,
    /// wall-clock seconds spent training (0 when loaded from cache)
    pub train_secs: f64,
    /// wall-clock seconds spent encoding the base set
    pub encode_secs: f64,
}

/// Queries per `search_batch` call in the harness: large enough to
/// amortize batched LUT build and decode, small enough to bound the
/// rerank working set (~batch × rerank_l × dim floats).
const EVAL_BATCH: usize = 128;

impl Experiment {
    /// Run the full query set through the batch engine (the same
    /// `search_batch` plan the serving path executes, in bounded
    /// batches) and compute Recall@{1,10,100}.
    pub fn run_recall(&self, search: SearchConfig) -> Recall {
        let engine = SearchEngine::new(self.quant.as_ref(), &self.index, search);
        let exec = Executor::new(search.num_threads);
        let queries: Vec<&[f32]> = (0..self.splits.query.len())
            .map(|qi| self.splits.query.row(qi))
            .collect();
        let mut results = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(EVAL_BATCH) {
            results.extend(engine.search_batch_on(&exec, chunk));
        }
        recall(&results, &self.gt)
    }

    /// One point of the recall-vs-nprobe trade-off curve.
    pub fn sweep_point(&self, ivf: &IvfIndex, search: SearchConfig)
                       -> NprobePoint {
        let exec = Executor::new(search.num_threads);
        let queries: Vec<&[f32]> = (0..self.splits.query.len())
            .map(|qi| self.splits.query.row(qi))
            .collect();
        let mut results = Vec::with_capacity(queries.len());
        let t0 = Instant::now();
        for chunk in queries.chunks(EVAL_BATCH) {
            let req = crate::index::SearchRequest::from_config(
                &search, vec![search.k; chunk.len()]);
            results.extend(ivf.search_batch_on(
                self.quant.as_ref(), &exec, chunk, &req)
                .expect("ivf batch plan"));
        }
        let secs = t0.elapsed().as_secs_f64();
        NprobePoint {
            nprobe: if search.nprobe == 0 { ivf.num_lists() }
                    else { search.nprobe.min(ivf.num_lists()) },
            recall: recall(&results, &self.gt),
            secs_per_query: secs / queries.len().max(1) as f64,
        }
    }

    /// [`Experiment::sweep_point`] on the disk tier: same measurement,
    /// plus error surfacing from the lazy block fetches (cache state
    /// carries across calls, so repeated points measure a warming
    /// cache — exactly what the tier serves in practice).
    pub fn sweep_point_disk(&self, disk: &DiskIvfIndex,
                            search: SearchConfig) -> Result<NprobePoint> {
        let exec = Executor::new(search.num_threads);
        let queries: Vec<&[f32]> = (0..self.splits.query.len())
            .map(|qi| self.splits.query.row(qi))
            .collect();
        let mut results = Vec::with_capacity(queries.len());
        let t0 = Instant::now();
        for chunk in queries.chunks(EVAL_BATCH) {
            let req = crate::index::SearchRequest::from_config(
                &search, vec![search.k; chunk.len()]);
            results.extend(disk.search_batch_on(
                self.quant.as_ref(), &exec, chunk, &req)?);
        }
        let secs = t0.elapsed().as_secs_f64();
        Ok(NprobePoint {
            nprobe: if search.nprobe == 0 { disk.num_lists() }
                    else { search.nprobe.min(disk.num_lists()) },
            recall: recall(&results, &self.gt),
            secs_per_query: secs / queries.len().max(1) as f64,
        })
    }

    /// The recall@R-vs-nprobe sweep: run the full query set through the
    /// IVF backend at each `nprobe` and report recall + per-query time
    /// (the sub-linear trade-off curve `unq ivf-sweep` and the bench
    /// record).
    pub fn run_ivf_nprobe_sweep(&self, ivf: &IvfIndex, search: SearchConfig,
                                nprobes: &[usize]) -> Vec<NprobePoint> {
        nprobes
            .iter()
            .map(|&np| {
                let mut s = search;
                s.nprobe = np;
                self.sweep_point(ivf, s)
            })
            .collect()
    }

    /// One measured point of the scan-precision trade-off: run the full
    /// query set at `search.scan_precision` and report recall + per-query
    /// latency.
    pub fn precision_point(&self, search: SearchConfig) -> PrecisionPoint {
        let queries: Vec<&[f32]> = (0..self.splits.query.len())
            .map(|qi| self.splits.query.row(qi))
            .collect();
        let engine =
            SearchEngine::new(self.quant.as_ref(), &self.index, search);
        let exec = Executor::new(search.num_threads);
        let t0 = Instant::now();
        let mut results = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(EVAL_BATCH) {
            results.extend(engine.search_batch_on(&exec, chunk));
        }
        let secs = t0.elapsed().as_secs_f64();
        PrecisionPoint {
            precision: search.scan_precision,
            recall: recall(&results, &self.gt),
            secs_per_query: secs / queries.len().max(1) as f64,
        }
    }

    /// The throughput × recall sweep over scan precisions (`unq
    /// precision-sweep`, and the bench record in `BENCH_scan.json`).
    /// Packs the index once when any integer precision is requested.
    pub fn run_precision_sweep(&mut self, search: SearchConfig,
                               precisions: &[ScanPrecision])
                               -> Vec<PrecisionPoint> {
        if precisions.iter().any(|&p| p != ScanPrecision::F32) {
            self.index.ensure_packed();
        }
        precisions
            .iter()
            .map(|&p| {
                let mut s = search;
                s.scan_precision = p;
                self.precision_point(s)
            })
            .collect()
    }

    /// The filtered-search selectivity curve (`unq eval
    /// --filter-selectivity`): for each modulus `m`, tag the flat
    /// index `id % m` and run the query set under the predicate
    /// `tag=0` — admitting ~`1/m` of the rows inside the scan kernels
    /// (rust/DESIGN.md §13).  Reports per-query latency next to the
    /// `filter.*` pruning counters, and asserts the in-scan filter
    /// never leaks an inadmissible row.
    pub fn run_filter_selectivity(&mut self, search: SearchConfig,
                                  moduli: &[u64]) -> Vec<FilterPoint> {
        let n = self.index.n as u64;
        let queries: Vec<&[f32]> = (0..self.splits.query.len())
            .map(|qi| self.splits.query.row(qi))
            .collect();
        let exec = Executor::new(search.num_threads);
        let mut out = Vec::with_capacity(moduli.len());
        for &m in moduli {
            assert!(m > 0, "selectivity modulus must be positive");
            self.index.set_tags((0..n).map(|i| i % m).collect());
            let mut s = search;
            s.filter = Some(crate::index::Filter::TagEq(0));
            let engine =
                SearchEngine::new(self.quant.as_ref(), &self.index, s);
            let obs0 = crate::obs::global().snapshot();
            let t0 = Instant::now();
            let mut results = Vec::with_capacity(queries.len());
            for chunk in queries.chunks(EVAL_BATCH) {
                results.extend(engine.search_batch_on(&exec, chunk));
            }
            let secs = t0.elapsed().as_secs_f64();
            let d = crate::obs::global().snapshot().delta(&obs0);
            for (qi, ids) in results.iter().enumerate() {
                for &id in ids {
                    assert_eq!(
                        u64::from(id) % m, 0,
                        "query {qi}: filtered search leaked id {id} \
                         under tag = id % {m}"
                    );
                }
            }
            out.push(FilterPoint {
                modulus: m,
                selectivity: 1.0 / m as f64,
                rows_pruned: d.counter("filter.rows_pruned"),
                bitmaps_built: d.counter("filter.bitmaps_built"),
                secs_per_query: secs / queries.len().max(1) as f64,
            });
        }
        out
    }

    /// Per-query mean latency of the two-stage batch search, in seconds.
    pub fn measure_latency(&self, search: SearchConfig, queries: usize) -> f64 {
        let engine = SearchEngine::new(self.quant.as_ref(), &self.index, search);
        let exec = Executor::new(search.num_threads);
        let nq = queries.min(self.splits.query.len());
        let queries: Vec<&[f32]> =
            (0..nq).map(|qi| self.splits.query.row(qi)).collect();
        let t0 = Instant::now();
        for chunk in queries.chunks(EVAL_BATCH) {
            std::hint::black_box(engine.search_batch_on(&exec, chunk));
        }
        t0.elapsed().as_secs_f64() / nq.max(1) as f64
    }
}

/// One measured point of the recall-vs-nprobe curve.
#[derive(Clone, Copy, Debug)]
pub struct NprobePoint {
    pub nprobe: usize,
    pub recall: Recall,
    pub secs_per_query: f64,
}

/// One measured point of the recall-vs-scan-precision curve.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionPoint {
    pub precision: ScanPrecision,
    pub recall: Recall,
    pub secs_per_query: f64,
}

/// One measured point of the filtered-search selectivity curve.
#[derive(Clone, Copy, Debug)]
pub struct FilterPoint {
    /// rows are tagged `id % modulus`; the predicate admits `tag=0`
    pub modulus: u64,
    /// admitted fraction of the base set (`1/modulus`)
    pub selectivity: f64,
    /// `filter.rows_pruned` delta over the sweep point
    pub rows_pruned: u64,
    /// `filter.bitmaps_built` delta over the sweep point
    pub bitmaps_built: u64,
    pub secs_per_query: f64,
}

fn model_cache_path(cfg: &AppConfig, kind: QuantizerKind) -> PathBuf {
    cfg.runs_dir.join(format!(
        "model_{}_{}_{}b.store",
        cfg.dataset,
        kind.name().replace(['+', ' '], "_"),
        cfg.bytes_per_vector
    ))
}

fn codes_cache_path(cfg: &AppConfig, kind: QuantizerKind, n_base: usize,
                    variant: &str) -> PathBuf {
    cfg.runs_dir.join(format!(
        "codes_{}_{}_{}b_n{}{}.store",
        cfg.dataset,
        kind.name().replace(['+', ' '], "_"),
        cfg.bytes_per_vector,
        n_base,
        if variant.is_empty() { String::new() } else { format!("_{variant}") }
    ))
}

fn ivf_cache_path(cfg: &AppConfig, kind: QuantizerKind, n_base: usize,
                  variant: &str) -> PathBuf {
    cfg.runs_dir.join(format!(
        "ivf_{}_{}_{}b_n{}_L{}{}{}.store",
        cfg.dataset,
        kind.name().replace(['+', ' '], "_"),
        cfg.bytes_per_vector,
        n_base,
        cfg.ivf.num_lists,
        if cfg.ivf.residual { "_res" } else { "" },
        if variant.is_empty() { String::new() } else { format!("_{variant}") }
    ))
}

/// Build the IVF index for a prepared experiment, or load it from the
/// runs cache (coarse centroids + list layout + codes persist through
/// [`crate::store`]).
///
/// The coarse codebook trains on the training split; with
/// `cfg.ivf.residual` the *fine* quantizer is used as-is (the residual
/// contract: its LUT estimates squared distance in whatever space it was
/// trained on — see rust/DESIGN.md §5).
pub fn build_or_load_ivf(cfg: &AppConfig, quant: &dyn Quantizer,
                         train: &Dataset, base: &Dataset, variant: &str)
                         -> Result<IvfIndex> {
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let path = ivf_cache_path(cfg, cfg.quantizer, base.len(), variant);
    let mut ivf = if path.exists() {
        IvfIndex::load(&Store::load(&path)?)?
    } else {
        let t0 = Instant::now();
        eprintln!("[harness] building IVF (L={} residual={}) over {} vectors",
                  cfg.ivf.num_lists, cfg.ivf.residual, base.len());
        let coarse = CoarseQuantizer::train(&train.data, train.dim,
                                            cfg.ivf.num_lists, 0, 15);
        let ivf = IvfIndex::build(quant, base, coarse, cfg.ivf.residual);
        eprintln!("[harness] built IVF in {:.1}s", t0.elapsed().as_secs_f64());
        let mut store = Store::new();
        ivf.save(&mut store);
        store.save(&path)?;
        ivf
    };
    // the integer scan precisions read the blocked mirror; build it once
    // here rather than per search
    if cfg.search.scan_precision != ScanPrecision::F32 {
        ivf.ensure_packed();
    }
    // the 1-bit pre-filter reads row sketches; build them once up front
    // (non-residual only — residual search keeps the plan off)
    if cfg.search.prefilter && !cfg.ivf.residual {
        ivf.ensure_sketches(quant);
    }
    Ok(ivf)
}

/// Build (or reuse) the disk-tier block archive for a prepared
/// experiment and open it for lazy serving under the configured
/// hot-list cache budget (`cfg.ivf.cache_mb`).  The archive derives
/// from the RAM index ([`build_or_load_ivf`]), so both tiers always
/// serve exactly the same layout; a sketch-bearing archive gets its
/// own cache file because the per-list payloads differ.
pub fn build_or_load_disk_ivf(cfg: &AppConfig, quant: &dyn Quantizer,
                              train: &Dataset, base: &Dataset,
                              variant: &str) -> Result<DiskIvfIndex> {
    let ivf = build_or_load_ivf(cfg, quant, train, base, variant)?;
    let stem = ivf_cache_path(cfg, cfg.quantizer, base.len(), variant);
    let suffix =
        if ivf.codes.sketches.is_some() { ".pf.blocks" } else { ".blocks" };
    let path = PathBuf::from(format!("{}{}", stem.display(), suffix));
    if !path.exists() {
        eprintln!("[harness] writing disk-ivf archive {}", path.display());
        DiskIvfIndex::save_archive(&ivf, &path)?;
    }
    DiskIvfIndex::open(&path, cfg.ivf.cache_mb.saturating_mul(1 << 20))
}

/// Build an in-memory streaming index by inserting `base` in fixed-size
/// batches — the write path the recall gate and `unq ingest` verify
/// against the frozen engines.  External ids come out as `0..n` in row
/// order, so recall against the standard ground truth needs no remap.
pub fn stream_ingest(quant: &dyn Quantizer, base: &Dataset,
                     routing: Option<crate::index::Routing>,
                     scfg: crate::config::StreamConfig, batch: usize)
                     -> Result<crate::index::StreamingIndex> {
    let ix = crate::index::StreamingIndex::new(quant.code_bytes(), routing,
                                               scfg);
    let step = batch.max(1);
    for lo in (0..base.len()).step_by(step) {
        let hi = (lo + step).min(base.len());
        ix.insert_batch(quant, base.rows(lo, hi))?;
    }
    Ok(ix)
}

/// Train a shallow baseline or load it from the runs cache.
pub fn train_or_load_shallow(cfg: &AppConfig, kind: QuantizerKind,
                             train: &Dataset) -> Result<(Box<dyn Quantizer>, f64)> {
    let path = model_cache_path(cfg, kind);
    let dim = train.dim;
    let m = cfg.bytes_per_vector;
    let k = cfg.k_codewords;
    // additive methods spend one byte on the norm (DESIGN.md): m-1 codebooks
    let m_add = m.saturating_sub(1).max(1);

    if path.exists() {
        let store = Store::load(&path)?;
        let q: Box<dyn Quantizer> = match kind {
            QuantizerKind::Pq => Box::new(Pq::load(&store, "")?),
            QuantizerKind::Opq => Box::new(Opq::load(&store, "")?),
            QuantizerKind::Rvq | QuantizerKind::Lsq | QuantizerKind::LsqRerank =>
                Box::new(Additive::load(&store, "")?),
            QuantizerKind::CatalystLattice => {
                let map = lattice::CatalystMap::load(&store, "")?;
                let meta = store.get_meta("lattice").context("lattice meta")?;
                let parts: Vec<i64> =
                    meta.split(',').map(|p| p.parse().unwrap_or(0)).collect();
                Box::new(lattice::CatalystLattice {
                    map, r2: parts[0], nominal: parts[1] as usize,
                })
            }
            QuantizerKind::CatalystOpq => {
                let map = lattice::CatalystMap::load(&store, "cat_")?;
                let opq = Opq::load(&store, "")?;
                Box::new(lattice::CatalystOpq { map, opq })
            }
            QuantizerKind::UnqNative =>
                Box::new(NativeUnq::load(&store, "")?),
            QuantizerKind::Unq => bail!("UNQ is artifact-backed, not cached here"),
        };
        return Ok((q, 0.0));
    }

    let t0 = Instant::now();
    eprintln!("[harness] training {} on {} ({} vectors, {}B budget)",
              kind.name(), cfg.dataset, train.len(), m);
    let mut store = Store::new();
    let q: Box<dyn Quantizer> = match kind {
        QuantizerKind::Pq => {
            let q = Pq::train(&train.data, dim, m, k, 0, 15);
            q.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::Opq => {
            let q = Opq::train(&train.data, dim, m, k, 0, 4, 10);
            q.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::Rvq => {
            let q = Additive::train_rvq(&train.data, dim, m_add, k, 0, 12, "RVQ");
            q.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::Lsq | QuantizerKind::LsqRerank => {
            let q = lsq::train_lsq(&train.data, dim, m_add, k,
                                   &lsq::LsqConfig::default());
            q.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::CatalystLattice => {
            let q = lattice::CatalystLattice::train(&train.data, dim, m);
            q.map.save(&mut store, "");
            store.put_meta("lattice", &format!("{},{}", q.r2, q.nominal));
            Box::new(q)
        }
        QuantizerKind::CatalystOpq => {
            let q = lattice::CatalystOpq::train(&train.data, dim, m, k, 0);
            q.map.save(&mut store, "cat_");
            q.opq.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::UnqNative => {
            // the paper's DNN quantizer, trained from scratch in-process
            // (quant::unq_native; hyperparameters from cfg.unq_native —
            // note they do not key the cache path, so clear `runs/` to
            // retrain with different settings)
            let q = NativeUnq::train(&train.data, dim, m, k,
                                     &cfg.unq_native);
            q.save(&mut store, "");
            Box::new(q)
        }
        QuantizerKind::Unq => bail!("UNQ is artifact-backed; use load_unq"),
    };
    let secs = t0.elapsed().as_secs_f64();
    eprintln!("[harness] trained {} in {:.1}s", kind.name(), secs);
    store.save(&path)?;
    Ok((q, secs))
}

/// Resolve the UNQ artifact bundle name for a config (+ ablation variant).
pub fn unq_artifact_name(cfg: &AppConfig, variant: &str) -> String {
    if variant.is_empty() || variant == "unq" {
        // main bundles are trained on the 1M-scale split of each family
        let family = if cfg.dataset.starts_with("deep") { "deep1m" } else { "sift1m" };
        format!("{}_{}b", family, cfg.bytes_per_vector)
    } else {
        format!("abl_{variant}")
    }
}

/// Load the UNQ runtime + quantizer for a config. Returns an error whose
/// message mentions `make artifacts` when the bundle is missing.
pub fn load_unq(cfg: &AppConfig, variant: &str)
                -> Result<(UnqRuntime, UnqQuantizer)> {
    let name = unq_artifact_name(cfg, variant);
    let dir = cfg.artifacts_dir.join(&name);
    let rt = UnqRuntime::load(&dir)
        .with_context(|| format!("load UNQ artifact {name:?} — run `make artifacts`"))?;
    // probe all three graphs now: a broken runtime is a clean error at
    // load time, never a panic mid-scan (quant::unq failure contract)
    let q = UnqQuantizer::try_new(rt.handle.clone())
        .with_context(|| format!("UNQ artifact {name:?} failed its \
                                  construction probe"))?;
    Ok((rt, q))
}

/// Prepare the full experiment for one (dataset, method, bytes) cell.
/// `variant` selects a Table-5 ablation bundle for UNQ ("" for the paper
/// configuration).
pub fn prepare(cfg: &AppConfig, variant: &str) -> Result<Experiment> {
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let spec = data::spec_by_name(&cfg.dataset, cfg.scale)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
    let gt = crate::gt::load_or_compute(&cfg.data_dir, &spec.name,
                                        &splits.base, &splits.query, 100)?;

    let (runtime, quant, train_secs): (Option<UnqRuntime>, Box<dyn Quantizer>, f64) =
        if cfg.quantizer == QuantizerKind::Unq {
            let (rt, q) = load_unq(cfg, variant)?;
            (Some(rt), Box::new(q), 0.0)
        } else {
            let (q, secs) = train_or_load_shallow(cfg, cfg.quantizer, &splits.train)?;
            (None, q, secs)
        };

    // encode the base set (cached)
    let codes_path = codes_cache_path(cfg, cfg.quantizer, splits.base.len(), variant);
    let (mut index, encode_secs) = if codes_path.exists() {
        let store = Store::load(&codes_path)?;
        let (shape, codes) = store.get_u8("codes").context("codes blob")?;
        (CompressedIndex::from_codes(shape[0], shape[1], codes.to_vec()), 0.0)
    } else {
        let t0 = Instant::now();
        let index = CompressedIndex::build(quant.as_ref(), &splits.base);
        let secs = t0.elapsed().as_secs_f64();
        eprintln!("[harness] encoded {} vectors with {} in {:.1}s",
                  index.n, quant.name(), secs);
        let mut store = Store::new();
        store.put_u8("codes", &[index.n, index.stride], index.codes.clone());
        store.save(&codes_path)?;
        (index, secs)
    };
    // integer scan precisions read the blocked mirror; build it up front
    // so serving/eval paths never pay the on-the-fly transpose.  Only
    // for the flat backend — the IVF path packs its own per-list code
    // matrix in build_or_load_ivf, and mirroring the flat codes too
    // would hold ~n × stride dead bytes.
    if cfg.search.scan_precision != ScanPrecision::F32
        && cfg.ivf.backend == crate::config::IndexBackendKind::Flat
    {
        index.ensure_packed();
    }
    // likewise the 1-bit pre-filter's row sketches (quantizers without a
    // decoder return false and the search silently skips pruning)
    if cfg.search.prefilter
        && cfg.ivf.backend == crate::config::IndexBackendKind::Flat
    {
        index.ensure_sketches(quant.as_ref());
    }

    Ok(Experiment {
        cfg: cfg.clone(), splits, gt, runtime, quant, index,
        train_secs, encode_secs,
    })
}

/// The default search config for a (method, dataset) cell, following the
/// paper: rerank top-500 at "1M" scale, top-1000 at "1B" scale; LSQ-plain
/// and Catalyst rows search without reranking.
pub fn paper_search_config(kind: QuantizerKind, dataset: &str, k: usize)
                           -> SearchConfig {
    let rerank_l = if dataset.ends_with("1b") { 1000 } else { 500 };
    let no_rerank = matches!(
        kind,
        QuantizerKind::Pq | QuantizerKind::Opq | QuantizerKind::Rvq
            | QuantizerKind::Lsq | QuantizerKind::CatalystLattice
            | QuantizerKind::CatalystOpq
    );
    SearchConfig { rerank_l, k, no_rerank, ..SearchConfig::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn tiny_cfg(dir: &std::path::Path, kind: QuantizerKind) -> AppConfig {
        let mut cfg = AppConfig::default();
        cfg.dataset = "sift1m".into();
        cfg.quantizer = kind;
        cfg.bytes_per_vector = 8;
        cfg.k_codewords = 64; // small codebooks keep the test fast
        cfg.scale = 0.02;     // 2000 base vectors
        cfg.data_dir = dir.join("data");
        cfg.runs_dir = dir.join("runs");
        cfg.artifacts_dir = dir.join("artifacts");
        cfg
    }

    #[test]
    fn end_to_end_pq_recall_beats_random() {
        let dir = TempDir::new("harness").unwrap();
        let cfg = tiny_cfg(dir.path(), QuantizerKind::Pq);
        let exp = prepare(&cfg, "").unwrap();
        let r = exp.run_recall(SearchConfig {
            rerank_l: 100, k: 100, ..Default::default()
        });
        // random top-100 of 2000 would give R@100 ≈ 5%
        assert!(r.at100 > 30.0, "R@100 = {}", r.at100);
        assert!(r.at1 > 1.0, "R@1 = {}", r.at1);
        assert!(r.at1 <= r.at10 && r.at10 <= r.at100);
    }

    #[test]
    fn cache_reuse_second_prepare_is_trainless() {
        let dir = TempDir::new("harness").unwrap();
        let cfg = tiny_cfg(dir.path(), QuantizerKind::Pq);
        let first = prepare(&cfg, "").unwrap();
        assert!(first.train_secs > 0.0);
        let second = prepare(&cfg, "").unwrap();
        assert_eq!(second.train_secs, 0.0);
        assert_eq!(second.encode_secs, 0.0);
        assert_eq!(first.index.codes, second.index.codes);
    }

    #[test]
    fn ivf_sweep_recall_approaches_flat_and_caches() {
        let dir = TempDir::new("harness").unwrap();
        let mut cfg = tiny_cfg(dir.path(), QuantizerKind::Pq);
        cfg.ivf.num_lists = 8;
        cfg.ivf.residual = false;
        let exp = prepare(&cfg, "").unwrap();
        let ivf = build_or_load_ivf(&cfg, exp.quant.as_ref(),
                                    &exp.splits.train, &exp.splits.base, "")
            .unwrap();
        assert_eq!(ivf.n(), exp.index.n);
        let search = SearchConfig { rerank_l: 100, k: 100,
                                    ..Default::default() };
        let flat = exp.run_recall(search);
        let pts = exp.run_ivf_nprobe_sweep(&ivf, search, &[1, 8]);
        assert_eq!(pts[0].nprobe, 1);
        assert_eq!(pts[1].nprobe, 8);
        // nprobe = all lists (non-residual) is flat-identical, recall
        // included
        assert_eq!(pts[1].recall, flat);
        assert!(pts[1].recall.at100 + 1.0 >= pts[0].recall.at100,
                "more probes lost recall: {} vs {}",
                pts[1].recall.at100, pts[0].recall.at100);
        // second build hits the archive cache and searches identically
        let again = build_or_load_ivf(&cfg, exp.quant.as_ref(),
                                      &exp.splits.train, &exp.splits.base,
                                      "").unwrap();
        assert_eq!(again.remap, ivf.remap);
        assert_eq!(again.codes.codes, ivf.codes.codes);
    }

    #[test]
    fn disk_tier_sweep_matches_ram_and_reuses_archive() {
        let dir = TempDir::new("harness").unwrap();
        let mut cfg = tiny_cfg(dir.path(), QuantizerKind::Pq);
        cfg.ivf.num_lists = 8;
        cfg.ivf.cache_mb = 1;
        let exp = prepare(&cfg, "").unwrap();
        let ivf = build_or_load_ivf(&cfg, exp.quant.as_ref(),
                                    &exp.splits.train, &exp.splits.base, "")
            .unwrap();
        let disk = build_or_load_disk_ivf(
            &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
            "").unwrap();
        assert_eq!(disk.n(), ivf.n());
        let search = SearchConfig { rerank_l: 100, k: 100, nprobe: 3,
                                    ..Default::default() };
        let ram = exp.sweep_point(&ivf, search);
        let dsk = exp.sweep_point_disk(&disk, search).unwrap();
        assert_eq!(dsk.recall, ram.recall,
                   "disk tier must be recall-identical to RAM");
        assert_eq!(dsk.nprobe, ram.nprobe);
        // second build reuses the archive file (and still matches)
        let again = build_or_load_disk_ivf(
            &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
            "").unwrap();
        let pt = exp.sweep_point_disk(&again, search).unwrap();
        assert_eq!(pt.recall, ram.recall);
    }

    #[test]
    fn precision_sweep_recall_tracks_f32_and_packs_once() {
        let dir = TempDir::new("harness").unwrap();
        let mut cfg = tiny_cfg(dir.path(), QuantizerKind::Pq);
        cfg.search.scan_precision = ScanPrecision::U16;
        let mut exp = prepare(&cfg, "").unwrap();
        assert!(exp.index.is_packed(),
                "prepare must pack for integer precisions");
        let search = SearchConfig { rerank_l: 100, k: 100,
                                    ..Default::default() };
        let pts = exp.run_precision_sweep(
            search, &[ScanPrecision::F32, ScanPrecision::U16,
                      ScanPrecision::U8]);
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].precision, ScanPrecision::F32);
        // with rerank on, integer selection feeds the same exact d1
        // rerank — recall must stay in the same league as f32
        for pt in &pts[1..] {
            assert!(pt.recall.at100 + 10.0 >= pts[0].recall.at100,
                    "{:?} recall collapsed: {} vs f32 {}",
                    pt.precision, pt.recall.at100, pts[0].recall.at100);
        }
    }

    #[test]
    fn end_to_end_native_unq_trains_caches_and_searches() {
        let dir = TempDir::new("harness").unwrap();
        let mut cfg = tiny_cfg(dir.path(), QuantizerKind::UnqNative);
        cfg.k_codewords = 16;
        cfg.scale = 0.01; // 1000 base vectors: keep the debug test fast
        // tiny training budget: the PQ-equivalent init does the heavy
        // lifting, two epochs exercise the full optimization path
        cfg.unq_native.hidden = 16;
        cfg.unq_native.epochs = 2;
        cfg.unq_native.batch = 256;
        cfg.unq_native.kmeans_iters = 6;
        let exp = prepare(&cfg, "").unwrap();
        assert!(exp.train_secs > 0.0, "first prepare must train");
        assert_eq!(exp.quant.name(), "UNQ-native");
        // small rerank depth: the decoder MLP dominates debug-mode time
        let search = SearchConfig { rerank_l: 20, k: 20,
                                    ..Default::default() };
        let r = exp.run_recall(search);
        // random top-10 of 1000 would give R@10 ≈ 1%
        assert!(r.at10 > 20.0, "R@10 = {}", r.at10);
        assert!(r.at1 <= r.at10 && r.at10 <= r.at100);
        // second prepare loads the trained model from the runs cache and
        // reproduces the identical index
        let again = prepare(&cfg, "").unwrap();
        assert_eq!(again.train_secs, 0.0, "second prepare must hit cache");
        assert_eq!(again.index.codes, exp.index.codes);
        // the trait object plugs into the IVF read path unchanged:
        // nprobe = all lists (non-residual) is flat-identical
        let mut icfg = cfg.clone();
        icfg.ivf.num_lists = 8;
        let ivf = build_or_load_ivf(&icfg, exp.quant.as_ref(),
                                    &exp.splits.train, &exp.splits.base,
                                    "").unwrap();
        let pts = exp.run_ivf_nprobe_sweep(&ivf, search, &[8]);
        assert_eq!(pts[0].recall, r, "ivf@all must equal flat");
        // ... and into the streaming write path: fresh inserts serve
        // flat-identical ids
        let stream = stream_ingest(
            exp.quant.as_ref(), &exp.splits.base, None,
            crate::config::StreamConfig { segment_rows: 256,
                                          ..Default::default() },
            300).unwrap();
        let exec = Executor::new(search.num_threads);
        let queries: Vec<&[f32]> = (0..exp.splits.query.len())
            .map(|qi| exp.splits.query.row(qi))
            .collect();
        let mut results = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(128) {
            let req = crate::index::SearchRequest::from_config(
                &search, vec![search.k; chunk.len()]);
            results.extend(stream.search_batch_on(
                exp.quant.as_ref(), &exec, chunk, &req));
        }
        assert_eq!(super::recall(&results, &exp.gt), r,
                   "streaming must equal flat for fresh inserts");
    }

    #[test]
    fn paper_search_defaults() {
        let s = paper_search_config(QuantizerKind::Lsq, "sift1m", 100);
        assert!(s.no_rerank);
        let s = paper_search_config(QuantizerKind::LsqRerank, "sift1b", 100);
        assert!(!s.no_rerank);
        assert_eq!(s.rerank_l, 1000);
        let s = paper_search_config(QuantizerKind::Unq, "deep1m", 100);
        assert!(!s.no_rerank);
        assert_eq!(s.rerank_l, 500);
    }

    #[test]
    fn unq_without_artifacts_gives_actionable_error() {
        let dir = TempDir::new("harness").unwrap();
        let cfg = tiny_cfg(dir.path(), QuantizerKind::Unq);
        let err = match prepare(&cfg, "") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(err.contains("make artifacts"), "err: {err}");
    }
}
