//! Regenerate every table of the paper's evaluation (§4.1–§4.4) on the
//! simulated corpora.  Shared by the CLI (`unq tables`) and the bench
//! targets; rendered tables are persisted under `runs/tables/` so the
//! rust/DESIGN.md §4 entries are reproducible.

use anyhow::Context;

use crate::config::{AppConfig, QuantizerKind};
use crate::eval::harness::{self, paper_search_config};
use crate::eval::{Recall, Row, Table};
use crate::util::json::Json;
use crate::Result;

/// Methods in each recall table, in the paper's row order.
pub fn table2_methods() -> Vec<QuantizerKind> {
    use QuantizerKind::*;
    vec![Opq, CatalystOpq, CatalystLattice, Lsq, LsqRerank, Unq]
}

pub fn table34_methods() -> Vec<QuantizerKind> {
    use QuantizerKind::*;
    vec![CatalystLattice, Lsq, LsqRerank, Unq]
}

/// One recall cell; logs progress and tolerates missing UNQ artifacts by
/// returning `None` (the table prints a dash).
pub fn recall_cell(cfg: &AppConfig, kind: QuantizerKind, variant: &str)
                   -> Option<Recall> {
    let mut cfg = cfg.clone();
    cfg.quantizer = kind;
    match harness::prepare(&cfg, variant) {
        Ok(exp) => {
            let search = paper_search_config(kind, &cfg.dataset, 100);
            let r = exp.run_recall(search);
            eprintln!("[tables] {} / {} / {}B{}: R@1 {:.1} R@10 {:.1} R@100 {:.1}",
                      cfg.dataset, kind.name(), cfg.bytes_per_vector,
                      if variant.is_empty() { String::new() }
                      else { format!(" [{variant}]") },
                      r.at1, r.at10, r.at100);
            Some(r)
        }
        Err(e) => {
            eprintln!("[tables] {} / {} skipped: {e:#}", cfg.dataset, kind.name());
            None
        }
    }
}

/// Build one of the paper's recall tables over (sift, deep) × budgets.
pub fn recall_table(title: &str, base: &AppConfig, sift: &str, deep: &str,
                    methods: &[QuantizerKind], budgets: &[usize]) -> Table {
    let mut table = Table::new(title, &[&format!("BigANN-sim ({sift})"),
                                        &format!("Deep-sim ({deep})")]);
    for &bytes in budgets {
        let section = format!("{bytes} bytes per vector");
        for &kind in methods {
            let mut cells = Vec::new();
            for ds in [sift, deep] {
                let mut cfg = base.clone();
                cfg.dataset = ds.to_string();
                cfg.bytes_per_vector = bytes;
                cells.push(recall_cell(&cfg, kind, ""));
            }
            table.push(&section, Row { method: kind.name().into(), cells });
        }
    }
    table
}

fn persist_table(cfg: &AppConfig, name: &str, rendered: &str) -> Result<()> {
    let dir = cfg.runs_dir.join("tables");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), rendered)?;
    Ok(())
}

/// Run the selected table(s): "1" | "2" | "3" | "4" | "5" | "mem" |
/// "timings" | "all".
pub fn run_tables(cfg: &AppConfig, which: &str) -> Result<()> {
    let run =
        |t: &str| which == "all" || which == t;

    if run("1") {
        table1_timings(&cfg)?;
    }
    if run("2") {
        let t = recall_table("Table 2 — 1M scale (sim: 100k)", &cfg,
                             "sift1m", "deep1m", &table2_methods(), &[8, 16]);
        println!("{}", t.render());
        persist_table(&cfg, "table2", &t.render())?;
    }
    if run("3") {
        let t = recall_table("Table 3 — 10M scale (sim: 300k)", &cfg,
                             "sift10m", "deep10m", &table34_methods(), &[8, 16]);
        println!("{}", t.render());
        persist_table(&cfg, "table3", &t.render())?;
    }
    if run("4") {
        let t = recall_table("Table 4 — 1B scale (sim: 1M)", &cfg,
                             "sift1b", "deep1b", &table34_methods(), &[8, 16]);
        println!("{}", t.render());
        persist_table(&cfg, "table4", &t.render())?;
    }
    if run("5") {
        table5_ablation(&cfg)?;
    }
    if run("mem") {
        table_memory(&cfg)?;
    }
    if run("timings") {
        table_timings(&cfg)?;
    }
    Ok(())
}

/// Table 1 (qualitative in the paper) — measured train + encode cost per
/// method, which substantiates the Low/High complexity labels.
pub fn table1_timings(cfg: &AppConfig) -> Result<()> {
    println!("== Table 1 — measured training/encoding complexity ==");
    println!("{:<18} {:>12} {:>16}", "Method", "train (s)", "encode (µs/vec)");
    for kind in [QuantizerKind::Opq, QuantizerKind::Lsq, QuantizerKind::Unq] {
        let mut c = cfg.clone();
        c.dataset = "sift1m".into();
        c.quantizer = kind;
        c.bytes_per_vector = 8;
        match harness::prepare(&c, "") {
            Ok(exp) => {
                // measure encode on a slice of the base set
                let n = exp.splits.base.len().min(2000);
                let t0 = std::time::Instant::now();
                let _ = exp.quant.encode_batch(exp.splits.base.rows(0, n));
                let enc = t0.elapsed().as_secs_f64() / n as f64 * 1e6;
                println!("{:<18} {:>12.1} {:>16.1}", kind.name(),
                         exp.train_secs, enc);
            }
            Err(e) => println!("{:<18} skipped: {e:#}", kind.name()),
        }
    }
    Ok(())
}

/// Table 5 — ablation on BigANN1M-sim @ 8 bytes.
pub fn table5_ablation(cfg: &AppConfig) -> Result<()> {
    let mut base = cfg.clone();
    base.dataset = "sift1m".into();
    base.bytes_per_vector = 8;
    base.quantizer = QuantizerKind::Unq;

    let mut table = Table::new("Table 5 — ablation (BigANN1M-sim, 8 bytes)",
                               &["BigANN1M-sim"]);
    // search-procedure ablations reuse the main model
    let search_variants: Vec<(&str, Box<dyn Fn(&mut AppConfig)>)> = vec![
        ("UNQ", Box::new(|_c: &mut AppConfig| {})),
        ("Exhaustive reranking", Box::new(|c: &mut AppConfig| {
            c.search.exhaustive_rerank = true;
        })),
        ("No reranking", Box::new(|c: &mut AppConfig| {
            c.search.no_rerank = true;
        })),
    ];
    for (label, tweak) in &search_variants {
        let mut c = base.clone();
        tweak(&mut c);
        let cell = match harness::prepare(&c, "") {
            Ok(exp) => {
                let mut search = paper_search_config(QuantizerKind::Unq,
                                                     &c.dataset, 100);
                search.no_rerank = c.search.no_rerank;
                search.exhaustive_rerank = c.search.exhaustive_rerank;
                // cap exhaustive rerank cost: decode full base once
                let r = exp.run_recall(search);
                eprintln!("[tables] ablation {label}: R@1 {:.1} R@10 {:.1} \
                           R@100 {:.1}", r.at1, r.at10, r.at100);
                Some(r)
            }
            Err(e) => {
                eprintln!("[tables] ablation {label} skipped: {e:#}");
                None
            }
        };
        table.push("ablation", Row { method: label.to_string(),
                                     cells: vec![cell] });
    }
    // training-objective ablations use dedicated artifact bundles
    for (label, variant) in [
        ("No triplet loss", "no_triplet"),
        ("Triplet only", "triplet_only"),
        ("UNQ w/o hard", "wo_hard"),
        ("UNQ w/o Gumbel", "wo_gumbel"),
        ("No regularizer", "no_reg"),
    ] {
        let cell = recall_cell(&base, QuantizerKind::Unq, variant);
        table.push("ablation", Row { method: label.to_string(),
                                     cells: vec![cell] });
    }
    println!("{}", table.render());
    persist_table(cfg, "table5", &table.render())?;
    Ok(())
}

/// §4.2 — additional memory consumption of UNQ vs the shallow baselines.
pub fn table_memory(cfg: &AppConfig) -> Result<()> {
    println!("== §4.2 — auxiliary model memory ==");
    println!("{:<16} {:>10} {:>14} {:>22}", "Budget", "params",
             "model (MB)", "amortized (B/vec @1M)");
    for bytes in [8usize, 16] {
        let name = format!("sift1m_{bytes}b");
        let dir = cfg.artifacts_dir.join(&name);
        match crate::runtime::Manifest::load(&dir) {
            Ok(m) => {
                let mb = m.param_bytes as f64 / 1e6;
                println!("{:<16} {:>10} {:>14.1} {:>22.4}",
                         format!("{bytes} bytes"), m.param_count, mb,
                         m.param_bytes as f64 / 1e6 / 1.0);
                let j = Json::obj(vec![
                    ("budget_bytes", Json::Num(bytes as f64)),
                    ("param_bytes", Json::Num(m.param_bytes as f64)),
                ]);
                let dir = cfg.runs_dir.join("tables");
                std::fs::create_dir_all(&dir)?;
                std::fs::write(dir.join(format!("mem_{bytes}b.json")),
                               j.render_pretty())
                    .context("persist mem table")?;
            }
            Err(e) => println!("{bytes} bytes: skipped ({e:#})"),
        }
    }
    Ok(())
}

/// §4.4 — encode / scan / rerank wall-clock timings.
pub fn table_timings(cfg: &AppConfig) -> Result<()> {
    println!("== §4.4 — timings (single CPU core; paper: GPU encode, CPU scan) ==");
    let mut c = cfg.clone();
    c.dataset = "deep1m".into();
    c.bytes_per_vector = 8;
    for kind in [QuantizerKind::Unq, QuantizerKind::CatalystLattice,
                 QuantizerKind::Lsq] {
        c.quantizer = kind;
        match harness::prepare(&c, "") {
            Ok(exp) => {
                let n = exp.splits.base.len().min(5000);
                let t0 = std::time::Instant::now();
                let _ = exp.quant.encode_batch(exp.splits.base.rows(0, n));
                let enc_per_m = t0.elapsed().as_secs_f64() / n as f64 * 1e6;
                // scan timing
                let lut = exp.quant.lut(exp.splits.query.row(0));
                let t1 = std::time::Instant::now();
                let reps = 20;
                for _ in 0..reps {
                    std::hint::black_box(crate::index::scan_topk(
                        &lut, &exp.index, 500));
                }
                let scan_ms = t1.elapsed().as_secs_f64() / reps as f64 * 1e3;
                // rerank timing (1000 candidates, as the paper's 1B setup)
                let rer_ms = if exp.quant.supports_rerank() {
                    let cands: Vec<u32> =
                        (0..1000.min(exp.index.n as u32)).collect();
                    let eng = crate::index::SearchEngine::new(
                        exp.quant.as_ref(), &exp.index,
                        paper_search_config(kind, &c.dataset, 100));
                    let t2 = std::time::Instant::now();
                    for _ in 0..5 {
                        std::hint::black_box(
                            eng.rerank(exp.splits.query.row(0), &cands, 100));
                    }
                    Some(t2.elapsed().as_secs_f64() / 5.0 * 1e3)
                } else {
                    None
                };
                println!(
                    "{:<18} encode 1M-extrapolated {:>7.2} s   scan({} vecs) \
                     {:>7.2} ms   rerank-1000 {}",
                    kind.name(),
                    enc_per_m,              // µs/vec == s per 1M vectors
                    exp.index.n,
                    scan_ms,
                    rer_ms.map(|v| format!("{v:.1} ms"))
                          .unwrap_or_else(|| "n/a".into())
                );
            }
            Err(e) => println!("{:<18} skipped: {e:#}", kind.name()),
        }
    }
    Ok(())
}
