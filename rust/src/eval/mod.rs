//! Evaluation harness: Recall@k and paper-style table rendering.
//!
//! Recall@k here follows the paper (and the TEXMEX convention): the
//! probability that the query's *true nearest neighbor* appears among the
//! k results returned from the compressed index.

pub mod harness;
pub mod tables;

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::gt::GroundTruth;

/// Recall@{1,10,100} triple, in percent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Recall {
    pub at1: f32,
    pub at10: f32,
    pub at100: f32,
}

impl Recall {
    pub fn get(&self, k: usize) -> f32 {
        match k {
            1 => self.at1,
            10 => self.at10,
            100 => self.at100,
            _ => panic!("recall tracked only at 1/10/100"),
        }
    }
}

/// Compute Recall@{1,10,100} of per-query result id lists against GT.
///
/// `results[q]` must be sorted best-first; missing entries count as miss.
pub fn recall(results: &[Vec<u32>], gt: &GroundTruth) -> Recall {
    assert_eq!(results.len(), gt.neighbors.len(), "query count mismatch");
    let nq = results.len().max(1);
    let mut hits = [0usize; 3];
    for q in 0..results.len() {
        let nn = gt.neighbors[q][0] as u32;
        for (slot, k) in [1usize, 10, 100].iter().enumerate() {
            if results[q].iter().take(*k).any(|&id| id == nn) {
                hits[slot] += 1;
            }
        }
    }
    Recall {
        at1: 100.0 * hits[0] as f32 / nq as f32,
        at10: 100.0 * hits[1] as f32 / nq as f32,
        at100: 100.0 * hits[2] as f32 / nq as f32,
    }
}

/// One rendered table: method rows × (dataset, byte-budget) recall cells.
#[derive(Default)]
pub struct Table {
    pub title: String,
    /// (section label e.g. "8 bytes per vector") → rows
    pub sections: BTreeMap<String, Vec<Row>>,
    /// column group labels, e.g. ["BigANN1M-sim", "Deep1M-sim"]
    pub datasets: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Row {
    pub method: String,
    /// per dataset: Recall triple (None = not run)
    pub cells: Vec<Option<Recall>>,
}

impl Table {
    pub fn new(title: &str, datasets: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            sections: BTreeMap::new(),
            datasets: datasets.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn push(&mut self, section: &str, row: Row) {
        assert_eq!(row.cells.len(), self.datasets.len());
        self.sections.entry(section.to_string()).or_default().push(row);
    }

    /// Render in the paper's layout (method | R@1 R@10 R@100 per dataset).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let mut header = format!("{:<20}", "Method");
        for d in &self.datasets {
            header.push_str(&format!(" | {:^23}", d));
        }
        let _ = writeln!(out, "{header}");
        let mut sub = format!("{:<20}", "");
        for _ in &self.datasets {
            sub.push_str(&format!(" | {:>6} {:>7} {:>7}", "R@1", "R@10", "R@100"));
        }
        let _ = writeln!(out, "{sub}");
        let _ = writeln!(out, "{}", "-".repeat(sub.len()));
        for (section, rows) in &self.sections {
            let _ = writeln!(out, "-- {section} --");
            for row in rows {
                let mut line = format!("{:<20}", row.method);
                for cell in &row.cells {
                    match cell {
                        Some(r) => line.push_str(&format!(
                            " | {:>6.1} {:>7.1} {:>7.1}", r.at1, r.at10, r.at100)),
                        None => line.push_str(&format!(
                            " | {:>6} {:>7} {:>7}", "-", "-", "-")),
                    }
                }
                let _ = writeln!(out, "{line}");
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gt_of(nns: &[i32]) -> GroundTruth {
        GroundTruth {
            r: 1,
            neighbors: nns.iter().map(|&n| vec![n]).collect(),
        }
    }

    #[test]
    fn perfect_results() {
        let gt = gt_of(&[5, 7]);
        let results = vec![vec![5, 1, 2], vec![7, 0, 3]];
        let r = recall(&results, &gt);
        assert_eq!(r.at1, 100.0);
        assert_eq!(r.at10, 100.0);
    }

    #[test]
    fn rank_sensitivity() {
        let gt = gt_of(&[5, 7, 9, 11]);
        // nn at ranks 1, 2, 11, missing
        let results = vec![
            vec![5],
            (0..12).map(|i| if i == 1 { 7 } else { i }).collect(),
            (0..20).map(|i| if i == 10 { 9 } else { i + 100 }).collect::<Vec<u32>>(),
            vec![1, 2, 3],
        ];
        let r = recall(&results, &gt);
        assert_eq!(r.at1, 25.0);
        assert_eq!(r.at10, 50.0);
        assert_eq!(r.at100, 75.0);
    }

    #[test]
    fn empty_results_are_misses() {
        let gt = gt_of(&[0]);
        let r = recall(&[vec![]], &gt);
        assert_eq!(r.at100, 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("Table 2 (sim)", &["BigANN1M", "Deep1M"]);
        t.push("8 bytes", Row {
            method: "OPQ".into(),
            cells: vec![
                Some(Recall { at1: 20.8, at10: 64.3, at100: 95.3 }),
                None,
            ],
        });
        let s = t.render();
        assert!(s.contains("OPQ"));
        assert!(s.contains("20.8"));
        assert!(s.contains("BigANN1M"));
        assert!(s.contains("8 bytes"));
        assert!(s.contains("R@100"));
    }

    #[test]
    #[should_panic]
    fn mismatched_cells_rejected() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push("s", Row { method: "m".into(), cells: vec![None] });
    }
}
