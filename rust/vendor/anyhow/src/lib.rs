//! Minimal in-tree stand-in for the `anyhow` error crate.
//!
//! The offline testbed has no crates.io access, so the real crate cannot
//! be fetched and a registry entry in `Cargo.lock` could never carry a
//! verifiable checksum.  This shim implements exactly the surface the
//! `unq` crate uses — [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros — with the same
//! semantics (context chains, `{}` = outermost frame, `{:#}` = full
//! chain) so the code above it is source-compatible with the real crate.
//!
//! Deliberately *not* implemented: backtraces, downcasting, and
//! `std::error::Error` for [`Error`] (the latter is load-bearing — the
//! blanket `From`/`Context` impls below are coherent only because
//! `Error` itself never implements `std::error::Error`, the same trick
//! the real crate uses).

use std::fmt::{self, Debug, Display};

/// A context-carrying error: an outermost message plus the chain of
/// causes beneath it (`chain[0]` is what `{}` prints; `{:#}` joins the
/// whole chain with `": "`, exactly like the real crate).
pub struct Error {
    chain: Vec<String>,
}

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap this error with an outer context frame.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, frame) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts via `?`, carrying its `source()` chain along
/// as context frames.  Coherent against `impl From<Error> for Error`
/// (core's reflexive impl) because `Error: !std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::{Display, Error};

    /// Internal dispatch for [`super::Context`]: one arm for genuine std
    /// errors, one for [`Error`] itself — disjoint because `Error` never
    /// implements `std::error::Error`.
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` (any error kind, including [`Error`] itself) and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_std_and_anyhow_results_and_options() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: inner");

        let o: Option<u32> = None;
        let e = o.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros_format_bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            ensure!(x < 10);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "x too small: 0");
        assert!(format!("{}", f(11).unwrap_err()).contains("x < 10"));
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e: Error = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by"));
    }
}
