//! Quickstart: compress a synthetic corpus with a product quantizer and
//! run compressed-domain search — no AOT artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use unq::config::SearchConfig;
use unq::data::{synthetic::Generator, Family};
use unq::gt;
use unq::index::{CompressedIndex, SearchEngine};
use unq::quant::{pq::Pq, Quantizer};

fn main() -> unq::Result<()> {
    // 1. Data: a SIFT-like synthetic corpus (see DESIGN.md §3).
    let gen = Generator::new(Family::SiftLike, 42);
    let train = gen.generate(0, 10_000);
    let base = gen.generate(1, 50_000);
    let queries = gen.generate(2, 100);
    println!("corpus: {} train / {} base / {} queries, dim {}",
             train.len(), base.len(), queries.len(), base.dim);

    // 2. Train an 8-byte product quantizer (K = 256 codewords/codebook).
    let pq = Pq::train(&train.data, train.dim, 8, 256, 0, 15);
    println!("trained {} → {} bytes/vector", pq.name(), pq.code_bytes());

    // 3. Compress the base set.
    let index = CompressedIndex::build(&pq, &base);
    println!("index: {} vectors, {} KB of codes",
             index.n, index.storage_bytes() / 1024);

    // 4. Batched two-stage search (ADC scan → decoder rerank), paper
    //    §3.3 — the whole query set goes through one QueryBatch ×
    //    IndexShard plan on a 2-thread executor.
    let engine = SearchEngine::new(&pq, &index, SearchConfig {
        rerank_l: 500, k: 10, num_threads: 2, shard_rows: 16_384,
        ..Default::default()
    });
    let truth = gt::brute_force(&base, &queries, 10);
    let qrefs: Vec<&[f32]> =
        (0..queries.len()).map(|qi| queries.row(qi)).collect();
    let results = engine.search_batch(&qrefs);
    let hits = results
        .iter()
        .enumerate()
        .filter(|(qi, result)| result.contains(&(truth.nn(*qi) as u32)))
        .count();
    println!("Recall@10 over {} queries: {:.1}%",
             queries.len(), 100.0 * hits as f32 / queries.len() as f32);
    Ok(())
}
