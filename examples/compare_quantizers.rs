//! Compare every shallow quantizer family on both synthetic descriptor
//! families — a self-contained miniature of the paper's Table 2 that
//! trains in-process (no cache, no artifacts) so it always runs.
//!
//! ```bash
//! cargo run --release --example compare_quantizers
//! ```

use std::time::Instant;

use unq::config::SearchConfig;
use unq::data::{synthetic::Generator, Family};
use unq::eval::{recall, Recall};
use unq::gt;
use unq::index::{CompressedIndex, SearchEngine};
use unq::quant::{additive::Additive, lattice, lsq, opq::Opq, pq::Pq, Quantizer};

fn eval_one(q: &dyn Quantizer, base: &unq::data::Dataset,
            queries: &unq::data::Dataset, truth: &gt::GroundTruth) -> Recall {
    let index = CompressedIndex::build(q, base);
    // batch-first: all queries through one executor plan (2 workers)
    let engine = SearchEngine::new(q, &index, SearchConfig {
        rerank_l: 200,
        k: 100,
        no_rerank: !q.supports_rerank(),
        num_threads: 2,
        shard_rows: 8192,
        ..Default::default()
    });
    let qrefs: Vec<&[f32]> =
        (0..queries.len()).map(|qi| queries.row(qi)).collect();
    let results = engine.search_batch(&qrefs);
    recall(&results, truth)
}

fn main() -> unq::Result<()> {
    let bytes = 8usize;
    for family in [Family::SiftLike, Family::DeepLike] {
        let gen = Generator::new(family, 7);
        let train = gen.generate(0, 8_000);
        let base = gen.generate(1, 20_000);
        let queries = gen.generate(2, 200);
        let truth = gt::brute_force(&base, &queries, 1);
        println!("\n=== {family:?} (dim {}, {} base, {} B/vec) ===",
                 base.dim, base.len(), bytes);
        println!("{:<20} {:>6} {:>7} {:>7} {:>10}",
                 "method", "R@1", "R@10", "R@100", "train(s)");

        let mut report = |name: &str, q: &dyn Quantizer, secs: f64| {
            let r = eval_one(q, &base, &queries, &truth);
            println!("{:<20} {:>6.1} {:>7.1} {:>7.1} {:>10.1}",
                     name, r.at1, r.at10, r.at100, secs);
        };

        let t = Instant::now();
        let pq = Pq::train(&train.data, train.dim, bytes, 256, 0, 12);
        report("PQ", &pq, t.elapsed().as_secs_f64());

        let t = Instant::now();
        let opq = Opq::train(&train.data, train.dim, bytes, 256, 0, 3, 10);
        report("OPQ", &opq, t.elapsed().as_secs_f64());

        let t = Instant::now();
        let rvq = Additive::train_rvq(&train.data, train.dim, bytes - 1, 256,
                                      0, 10, "RVQ");
        report("RVQ", &rvq, t.elapsed().as_secs_f64());

        let t = Instant::now();
        let lsq = lsq::train_lsq(&train.data, train.dim, bytes - 1, 256,
                                 &lsq::LsqConfig::default());
        report("LSQ", &lsq, t.elapsed().as_secs_f64());

        let t = Instant::now();
        let lat = lattice::CatalystLattice::train(&train.data, train.dim, bytes);
        report("Catalyst+Lattice", &lat, t.elapsed().as_secs_f64());
    }
    Ok(())
}
