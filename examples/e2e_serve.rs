//! End-to-end driver (the rust/DESIGN.md §4 validation run): boots the FULL
//! three-layer stack and serves batched requests, proving the layers
//! compose:
//!
//!   L1/L2 — the trained UNQ model's Pallas-kernel graphs, AOT-lowered to
//!           HLO text by `make artifacts`;
//!   runtime — PJRT CPU client executing those graphs from Rust;
//!   L3 — the coordinator: dynamic batcher, sharded ADC scan, decoder
//!        rerank, metrics.
//!
//! Loads the `sift1m_8b` bundle (or the dataset named by UNQ_DATASET),
//! encodes the base split through the AOT encoder, serves 2 000
//! closed-loop queries from 4 clients, and reports throughput, latency
//! and Recall@10 — the numbers recorded in rust/DESIGN.md §4.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use unq::config::{AppConfig, QuantizerKind};
use unq::coordinator::demo::run_serve;

fn main() -> unq::Result<()> {
    let mut cfg = AppConfig::default().apply_env();
    cfg.dataset = std::env::var("UNQ_DATASET").unwrap_or_else(|_| "sift1m".into());
    cfg.quantizer = QuantizerKind::Unq;
    cfg.bytes_per_vector = 8;
    cfg.serve.max_batch = 16;
    cfg.serve.max_delay_us = 2000;
    cfg.serve.num_threads = 2;
    cfg.serve.shard_rows = 16_384;

    let queries: usize = std::env::var("UNQ_E2E_QUERIES")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(2000);

    println!("=== end-to-end serving: UNQ ({} B) on {} ===",
             cfg.bytes_per_vector, cfg.dataset);
    let report = run_serve(&cfg, queries)?;

    // Sanity gates for the e2e claim: real answers at real throughput.
    assert!(report.recall_at10 > 20.0,
            "e2e recall collapsed: {}", report.recall_at10);
    assert!(report.qps > 1.0, "no throughput: {}", report.qps);
    println!("e2e OK — all three layers composed");
    Ok(())
}
