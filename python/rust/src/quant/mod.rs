//! placeholder
