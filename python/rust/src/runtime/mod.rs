//! placeholder
