//! placeholder
