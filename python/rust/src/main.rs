fn main() { println!("unq"); }
