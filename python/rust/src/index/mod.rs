//! placeholder
