"""Pallas kernel: ADC (asymmetric-distance) scan over compressed codes.

Computes ``score[n] = Σ_m lut[m, codes[n, m]]`` — the lookup-table form of
the paper's compressed-domain distance ``d2`` (eq. 8, negated so larger is
closer).  Two in-kernel strategies:

* ``gather`` (default) — a VPU gather per codebook; mirrors what the Rust
  hot path does on CPU.
* ``onehot`` — materializes one-hot code indicators per block and contracts
  them against the LUT with an MXU matmul.  On a real TPU the systolic
  array makes this the faster form for large M·K; under interpret mode it
  exists to validate the algebra and to let the timing bench compare both.

Grid: ``(N / block_n,)``; each program loads a ``(block_n, M)`` code tile
plus the whole ``(M, K)`` LUT (8 KB at M=8, K=256) into VMEM.

The production scan lives in ``rust/src/index/scan.rs`` (the paper performs
this step on CPU); this kernel is the L1 twin used for the XLA-vs-native
comparison in the timings bench and as a building block for fully-fused
search graphs.  Oracle: ``ref_adc_scan``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .encoder_block import _pick_block


def _scan_gather_kernel(codes_ref, lut_ref, o_ref):
    codes = codes_ref[...]                        # (bn, M) int32
    lut = lut_ref[...].astype(jnp.float32)        # (M, K)
    m = codes.shape[1]
    acc = jnp.zeros((codes.shape[0],), jnp.float32)
    for j in range(m):                            # unrolled: M is static
        acc = acc + lut[j, codes[:, j]]
    o_ref[...] = acc


def _scan_onehot_kernel(codes_ref, lut_ref, o_ref, *, k: int):
    codes = codes_ref[...]                        # (bn, M) int32
    lut = lut_ref[...].astype(jnp.float32)        # (M, K)
    bn, m = codes.shape
    onehot = (codes[..., None] ==
              jnp.arange(k, dtype=jnp.int32)[None, None, :])
    onehot = onehot.astype(jnp.float32).reshape(bn, m * k)
    o_ref[...] = jnp.dot(onehot, lut.reshape(m * k),
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "strategy"))
def adc_scan(codes: jnp.ndarray, lut: jnp.ndarray, block_n: int = 1024,
             strategy: str = "gather") -> jnp.ndarray:
    """LUT scan ``score[n] = Σ_m lut[m, codes[n,m]]`` via Pallas.

    Args:
      codes: ``(N, M)`` int32 codes in ``[0, K)``.
      lut: ``(M, K)`` f32 lookup table for one query.
      block_n: database tile size per program.
      strategy: ``"gather"`` (VPU) or ``"onehot"`` (MXU contraction).
    Returns:
      ``(N,)`` f32 scores (larger = closer).
    """
    n, m = codes.shape
    m2, k = lut.shape
    assert m == m2
    bn = _pick_block(n, block_n)
    if strategy == "gather":
        kern = _scan_gather_kernel
    elif strategy == "onehot":
        kern = functools.partial(_scan_onehot_kernel, k=k)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return pl.pallas_call(
        kern,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(codes, lut)
