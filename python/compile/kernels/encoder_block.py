"""Pallas kernel: fused Linear + bias + ReLU block (the MLP hot spot).

This is the compute core of both the UNQ encoder and decoder: a dense
matmul with the BatchNorm inference transform folded into the weights
(``w' = w * s``, ``b' = b * s + t``) and the ReLU fused into the epilogue,
so one kernel invocation covers Linear→BN→ReLU of the paper's Figure 1.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the output
``(B, N)`` into ``(block_b, block_n)`` MXU-aligned tiles; each program
loads a ``(block_b, D)`` activation stripe and a ``(D, block_n)`` weight
stripe into VMEM and performs a single MXU matmul with fused
bias-add + ReLU epilogue on the VPU.  With the default ``block_b = 128``,
``block_n = 128`` and the model dims used here (D ≤ 1024) the VMEM
footprint is ``128*D + D*128 + 128*128`` f32 ≤ ~1.1 MB — far below the
~16 MB VMEM budget, leaving room for double buffering of the weight
stripes across grid steps.

On this testbed the kernel runs under ``interpret=True`` (CPU): the Mosaic
TPU lowering cannot execute on the CPU PJRT plugin.  Correctness is pinned
to ``ref.ref_linear_relu`` by the pytest suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_relu_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One ``(block_b, block_n)`` output tile: ``o = act(x @ w + b)``."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target`` (MXU tile target)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("relu", "block_b", "block_n"))
def linear_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                relu: bool = True, block_b: int = 128,
                block_n: int = 128) -> jnp.ndarray:
    """Fused ``act(x @ w + b)`` via Pallas.

    Args:
      x: ``(B, D)`` input activations.
      w: ``(D, N)`` folded weight matrix.
      b: ``(N,)`` folded bias.
      relu: fuse a ReLU epilogue (False → plain affine, for head layers).
      block_b / block_n: output tile shape targets; shrunk to divisors of
        the actual dims so the grid tiles exactly.
    Returns:
      ``(B, N)`` f32 activations, numerically identical to
      ``ref_linear_relu``.
    """
    bsz, d = x.shape
    d2, n = w.shape
    assert d == d2, f"inner dim mismatch: {d} vs {d2}"
    assert b.shape == (n,)
    bb = _pick_block(bsz, block_b)
    bn = _pick_block(n, block_n)
    grid = (bsz // bb, n // bn)
    return pl.pallas_call(
        functools.partial(_linear_relu_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def mlp(x: jnp.ndarray, layers, final_relu: bool = False) -> jnp.ndarray:
    """Apply a stack of folded (w, b) layers with the fused kernel.

    ``layers`` is a sequence of ``(w, b)`` pairs; ReLU is applied between
    layers and optionally after the last one.
    """
    h = x
    last = len(layers) - 1
    for i, (w, b) in enumerate(layers):
        h = linear_relu(h, w, b, relu=(i != last) or final_relu)
    return h
