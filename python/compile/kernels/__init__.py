"""L1 — Pallas kernels for the UNQ compute hot spots.

All kernels run under ``interpret=True`` on this CPU testbed (real-TPU
Mosaic lowering cannot execute on the CPU PJRT plugin); each has a pure-jnp
oracle in :mod:`compile.kernels.ref` and a hypothesis-swept pytest pinning
the two together.
"""

from .encoder_block import linear_relu, mlp
from .heads import assign, heads_logits
from .scan import adc_scan
from . import ref

__all__ = [
    "linear_relu",
    "mlp",
    "assign",
    "heads_logits",
    "adc_scan",
    "ref",
]
