"""Pallas kernels: per-codebook head logits, hard assignment, and LUT build.

These kernels implement the learned-space geometry of UNQ §3.2–3.3:

* ``heads_logits``  — ``(B, M, dc) × (M, K, dc) → (B, M, K)`` dot products
  ``⟨net(x)_m, c_mk⟩``.  Used both to *encode* database vectors (argmax over
  K, eq. 4) and to build the per-query lookup table for the compressed-
  domain distance ``d2`` (eq. 8).
* ``assign``        — fused logits + argmax → ``(B, M)`` int32 codes.

TPU mapping: the grid is ``(B/block_b, M)`` — one program per (batch tile,
codebook).  Each program performs a ``(block_b, dc) @ (dc, K)`` MXU matmul;
for the assignment variant the argmax reduction over K runs on the VPU in
the same program, so codes never round-trip through HBM as full logits.
VMEM per program: ``block_b*dc + dc*K + block_b*K`` f32 — for the default
``block_b=128, dc=256, K=256`` that is ~0.4 MB.

Interpret-mode only on this CPU testbed; oracles: ``ref_heads_logits``,
``ref_assign``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .encoder_block import _pick_block


def _logits_kernel(h_ref, c_ref, o_ref):
    """One (batch-tile, codebook) program: ``o = h @ c^T``."""
    h = h_ref[...].astype(jnp.float32)            # (bb, 1, dc)
    c = c_ref[...].astype(jnp.float32)            # (1, K, dc)
    o_ref[...] = jnp.einsum(
        "bod,okd->bok", h, c, preferred_element_type=jnp.float32)


def _assign_kernel(h_ref, c_ref, o_ref):
    """Fused logits + argmax over K: ``o = argmax_k h @ c^T``."""
    h = h_ref[...].astype(jnp.float32)            # (bb, 1, dc)
    c = c_ref[...].astype(jnp.float32)            # (1, K, dc)
    logits = jnp.einsum(
        "bod,okd->bok", h, c, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def heads_logits(h: jnp.ndarray, codebooks: jnp.ndarray,
                 block_b: int = 128) -> jnp.ndarray:
    """Per-codebook dot products ``⟨h[b,m], c[m,k]⟩`` via Pallas.

    Args:
      h: ``(B, M, dc)`` encoder head outputs.
      codebooks: ``(M, K, dc)`` codewords.
    Returns:
      ``(B, M, K)`` f32 logits — the per-query LUT when ``h = net(q)``.
    """
    bsz, m, dc = h.shape
    m2, k, dc2 = codebooks.shape
    assert m == m2 and dc == dc2
    bb = _pick_block(bsz, block_b)
    return pl.pallas_call(
        _logits_kernel,
        grid=(bsz // bb, m),
        in_specs=[
            pl.BlockSpec((bb, 1, dc), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, dc), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1, k), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, k), jnp.float32),
        interpret=True,
    )(h, codebooks)


@functools.partial(jax.jit, static_argnames=("block_b",))
def assign(h: jnp.ndarray, codebooks: jnp.ndarray,
           block_b: int = 128) -> jnp.ndarray:
    """Hard codeword assignment (eq. 4) via a fused Pallas kernel.

    Args:
      h: ``(B, M, dc)`` encoder head outputs.
      codebooks: ``(M, K, dc)`` codewords.
    Returns:
      ``(B, M)`` int32 codes in ``[0, K)``.
    """
    bsz, m, dc = h.shape
    m2, k, dc2 = codebooks.shape
    assert m == m2 and dc == dc2
    bb = _pick_block(bsz, block_b)
    return pl.pallas_call(
        _assign_kernel,
        grid=(bsz // bb, m),
        in_specs=[
            pl.BlockSpec((bb, 1, dc), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, dc), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), jnp.int32),
        interpret=True,
    )(h, codebooks)
