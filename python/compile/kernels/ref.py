"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact functional twin here,
written with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` across a hypothesis-driven sweep
of shapes and dtypes; these functions are the single source of truth for
kernel semantics.

They are also reused by the L2 model (``compile.model``) for the *training*
graph, where interpret-mode Pallas would only slow things down: the exported
inference graphs call the Pallas kernels, training calls the refs, and the
test suite pins the two together.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_linear_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    relu: bool = True) -> jnp.ndarray:
    """Fused ``relu(x @ w + b)`` (the BN scale/shift is folded into w, b).

    Args:
      x: ``(B, D_in)`` activations.
      w: ``(D_in, D_out)`` folded weight.
      b: ``(D_out,)`` folded bias.
      relu: apply the ReLU nonlinearity (False for the final head layer).
    Returns:
      ``(B, D_out)`` activations in f32.
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b
    return jnp.maximum(y, 0.0) if relu else y


def ref_heads_logits(h: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Per-codebook dot products: the LUT / assignment scores.

    Args:
      h: ``(B, M, dc)`` encoder head outputs (one ``dc``-dim vector per
        codebook).
      codebooks: ``(M, K, dc)`` learned codewords.
    Returns:
      ``(B, M, K)`` logits ``⟨h[b,m], c[m,k]⟩``.
    """
    return jnp.einsum("bmd,mkd->bmk", h.astype(jnp.float32),
                      codebooks.astype(jnp.float32))


def ref_assign(h: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Hard codeword assignment: ``argmax_k ⟨h[b,m], c[m,k]⟩``.

    Returns ``(B, M)`` int32 codes.
    """
    return jnp.argmax(ref_heads_logits(h, codebooks), axis=-1).astype(jnp.int32)


def ref_adc_scan(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric-distance scan over compressed codes.

    ``score[n] = sum_m lut[m, codes[n, m]]`` — the compressed-domain
    (negated) ``d2`` of the paper, eq. (8): larger score = closer.

    Args:
      codes: ``(N, M)`` int32 codes in ``[0, K)``.
      lut: ``(M, K)`` per-query lookup table of dot products.
    Returns:
      ``(N,)`` f32 scores.
    """
    m_idx = jnp.arange(lut.shape[0])[None, :]  # (1, M)
    return jnp.sum(lut[m_idx, codes], axis=-1).astype(jnp.float32)


def ref_gather_codewords(codes: jnp.ndarray,
                         codebooks: jnp.ndarray) -> jnp.ndarray:
    """Gather the selected codewords and concatenate per vector.

    This is the decoder's input construction: ``(B, M)`` codes →
    ``(B, M*dc)`` concatenated codewords (the one-hot × codebook matmul).
    """
    b, m = codes.shape
    _, _, dc = codebooks.shape
    m_idx = jnp.arange(m)[None, :]
    gathered = codebooks[m_idx, codes]  # (B, M, dc)
    return gathered.reshape(b, m * dc).astype(jnp.float32)
