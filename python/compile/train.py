"""L2 — UNQ training (paper §3.4).

Implements the full training protocol of the paper:

* stochastic encoding with the **hard (straight-through) Gumbel-Softmax**
  trick (eqs. 2–5) — with ablation switches for the soft variant
  (``UNQ w/o hard``) and for the deterministic soft-to-hard annealing of
  Agustsson et al. (``UNQ w/o Gumbel``);
* reconstruction loss L1 (eq. 9), triplet loss L2 in the learned space
  (eq. 10) with positives from the top-3 true neighbors and negatives from
  ranks 100–200, resampled at every epoch start, and the squared
  coefficient-of-variation codeword-balance regularizer (eq. 11);
* the combined objective ``L = L1 + α·L2 + β·CV²`` (eq. 12) with β decayed
  linearly 1.0 → 0.05;
* **QHAdam** (Ma & Yarats 2018) with a **One-Cycle** learning-rate schedule
  (Smith & Topin 2017).

Training runs once, at build time, inside ``make artifacts``; nothing here
is ever on the Rust request path.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters of a UNQ training run (paper §3.4 + §4.1)."""

    steps: int = 3000
    batch: int = 256
    lr: float = 1e-3
    alpha: float = 0.01        # triplet weight (paper grid {.1,.01,.001})
    beta_start: float = 1.0    # CV² weight, linear 1.0 → 0.05
    beta_end: float = 0.05
    delta: float = 1.0         # triplet margin δ
    seed: int = 0
    # QHAdam (paper's recommended ν for QHAdam)
    nu1: float = 0.7
    nu2: float = 1.0
    beta1: float = 0.95
    beta2: float = 0.998
    eps: float = 1e-8
    # One-Cycle
    warmup_frac: float = 0.3
    div_factor: float = 10.0
    final_div: float = 100.0
    # ablation switches (Table 5)
    use_triplet: bool = True       # False → "No triplet loss" (α = 0)
    recon_weight: float = 1.0      # 0 → "Triplet only"
    use_hard: bool = True          # False → "UNQ w/o hard"
    use_gumbel: bool = True        # False → "UNQ w/o Gumbel" (soft-to-hard)
    use_cv_reg: bool = True        # False → "No regularizer" (β = 0)


# ---------------------------------------------------------------------------
# Schedules & optimizer
# ---------------------------------------------------------------------------


def one_cycle_lr(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """One-Cycle: cosine warmup lr/div→lr, cosine anneal lr→lr/final_div."""
    warm = cfg.warmup_frac * cfg.steps
    lo, hi = cfg.lr / cfg.div_factor, cfg.lr
    end = cfg.lr / cfg.final_div
    t = jnp.asarray(step, jnp.float32)

    def up(t):
        frac = t / jnp.maximum(warm, 1.0)
        return lo + (hi - lo) * 0.5 * (1 - jnp.cos(jnp.pi * frac))

    def down(t):
        frac = (t - warm) / jnp.maximum(cfg.steps - warm, 1.0)
        return end + (hi - end) * 0.5 * (1 + jnp.cos(jnp.pi * frac))

    return jnp.where(t < warm, up(t), down(t))


def beta_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear β decay 1.0 → 0.05 over training (paper §3.4)."""
    frac = jnp.asarray(step, jnp.float32) / max(cfg.steps - 1, 1)
    return cfg.beta_start + (cfg.beta_end - cfg.beta_start) * frac


def qhadam_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def qhadam_update(cfg: TrainConfig, grads, opt_state, params, lr):
    """One QHAdam step (Ma & Yarats 2018, alg. 1).

    ``θ ← θ - lr · [(1-ν1)g + ν1·m̂] / (sqrt[(1-ν2)g² + ν2·v̂] + ε)``
    with bias-corrected m̂, v̂.
    """
    t = opt_state["t"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** t.astype(jnp.float32)
    bc2 = 1.0 - b2 ** t.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, opt_state["m"], grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, opt_state["v"], grads)

    def upd(p, g, m, v):
        m_hat = m / bc1
        v_hat = v / bc2
        num = (1 - cfg.nu1) * g + cfg.nu1 * m_hat
        den = jnp.sqrt((1 - cfg.nu2) * g * g + cfg.nu2 * v_hat) + cfg.eps
        return p - lr * num / den

    new_params = jax.tree_util.tree_map(upd, params, grads, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "t": t}


# ---------------------------------------------------------------------------
# Stochastic encoders (eq. 5 + ablation variants)
# ---------------------------------------------------------------------------


def gumbel_softmax_st(key, log_p, use_hard: bool, use_gumbel: bool):
    """Relaxed one-hot sample over codewords, (B, M, K) → (B, M, K).

    * ``use_gumbel & use_hard``  — paper's UNQ: Gumbel noise + hard argmax
      with straight-through gradients.
    * ``use_gumbel & !use_hard`` — plain Gumbel-Softmax (Jang et al.).
    * ``!use_gumbel``            — deterministic softmax with ST hard
      assignment (soft-to-hard à la Agustsson et al., fixed temperature).
    """
    if use_gumbel:
        u = jax.random.uniform(key, log_p.shape, jnp.float32, 1e-20, 1.0)
        z = -jnp.log(-jnp.log(u))
        y_soft = jax.nn.softmax(log_p + z, axis=-1)
    else:
        y_soft = jax.nn.softmax(log_p, axis=-1)
    if not use_hard:
        return y_soft
    idx = jnp.argmax(y_soft, axis=-1)
    y_hard = jax.nn.one_hot(idx, log_p.shape[-1], dtype=jnp.float32)
    # Straight-through: forward = hard, backward = soft.
    return y_soft + jax.lax.stop_gradient(y_hard - y_soft)


# ---------------------------------------------------------------------------
# Loss (eq. 12)
# ---------------------------------------------------------------------------


def loss_fn(params, bn_state, key, x, x_pos, x_neg, beta, cfg: TrainConfig):
    """Full UNQ objective on one minibatch.

    Returns ``(loss, (new_bn_state, metrics))``.
    """
    b = x.shape[0]
    h, bn1 = M.encoder_apply(params, bn_state, x, train=True)
    logits = M.logits_from_heads(params, h)                  # (B, M, K)
    tau = jnp.exp(params["log_tau"])[None, :, None]
    log_p = jax.nn.log_softmax(logits / tau, axis=-1)        # eq. (2)

    onehot = gumbel_softmax_st(key, log_p, cfg.use_hard, cfg.use_gumbel)
    # Decoder input: soft/hard mixture over codewords, concatenated.
    mixed = jnp.einsum("bmk,mkd->bmd", onehot, params["codebooks"])
    gathered = mixed.reshape(b, -1)
    x_rec, bn2 = M.decoder_apply(params, bn1, gathered, train=True)

    l_rec = jnp.mean(jnp.sum((x - x_rec) ** 2, axis=-1))     # eq. (9)

    # Triplet loss in the learned space (eq. 10): d2(x, f(x±)) with hard
    # codes of the positive/negative (stop-grad through their assignment,
    # as the paper encodes them with the current model).
    if cfg.use_triplet:
        h_pos, _ = M.encoder_apply(params, bn_state, x_pos, train=False)
        h_neg, _ = M.encoder_apply(params, bn_state, x_neg, train=False)
        codes_pos = jax.lax.stop_gradient(
            ref.ref_assign(h_pos, params["codebooks"]))
        codes_neg = jax.lax.stop_gradient(
            ref.ref_assign(h_neg, params["codebooks"]))
        m_idx = jnp.arange(logits.shape[1])[None, :]
        d2_pos = -jnp.sum(logits[jnp.arange(b)[:, None], m_idx, codes_pos],
                          axis=-1)
        d2_neg = -jnp.sum(logits[jnp.arange(b)[:, None], m_idx, codes_neg],
                          axis=-1)
        l_trip = jnp.mean(jnp.maximum(0.0, cfg.delta + d2_pos - d2_neg))
    else:
        l_trip = jnp.zeros(())

    # CV² balance regularizer (eq. 11) over batch-averaged probabilities.
    p = jnp.exp(log_p)
    p_avg = jnp.mean(p, axis=0)                              # (M, K)
    mean = jnp.mean(p_avg, axis=-1)                          # (M,)
    var = jnp.var(p_avg, axis=-1)
    cv2 = jnp.mean(var / (mean ** 2 + 1e-10))
    if not cfg.use_cv_reg:
        cv2 = jax.lax.stop_gradient(cv2)

    alpha = cfg.alpha if cfg.use_triplet else 0.0
    beta_eff = beta if cfg.use_cv_reg else 0.0
    loss = (cfg.recon_weight * l_rec + alpha * l_trip + beta_eff * cv2)

    # Codeword usage entropy (monitoring; perplexity per codebook).
    usage = jnp.mean(jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1]),
                     axis=0)
    ent = -jnp.sum(usage * jnp.log(usage + 1e-10), axis=-1)
    metrics = {
        "loss": loss, "recon": l_rec, "triplet": l_trip, "cv2": cv2,
        "perplexity": jnp.mean(jnp.exp(ent)),
    }
    return loss, (bn2, metrics)


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(params, bn_state, opt_state, key, x, x_pos, x_neg, step,
               cfg: TrainConfig):
    """One jitted SGD step; returns (params, bn, opt, metrics)."""
    beta = beta_schedule(cfg, step)
    lr = one_cycle_lr(cfg, step)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    (_, (new_bn, metrics)), grads = grad_fn(
        params, bn_state, key, x, x_pos, x_neg, beta, cfg)
    new_params, new_opt = qhadam_update(cfg, grads, opt_state, params, lr)
    return new_params, new_bn, new_opt, metrics


# ---------------------------------------------------------------------------
# Triplet neighbor tables (paper: x⁺ ∈ top-3 NN, x⁻ ∈ ranks 100–200)
# ---------------------------------------------------------------------------


def neighbor_table(train: np.ndarray, pos_k: int = 3, neg_lo: int = 100,
                   neg_hi: int = 200, block: int = 1024) -> Tuple[np.ndarray, np.ndarray]:
    """Exact neighbor ranks of the training set against itself.

    Returns ``(pos, neg)``: ``pos[i]`` = indices of the top-``pos_k`` true
    nearest neighbors of row i (self excluded); ``neg[i]`` = indices at
    ranks ``[neg_lo, neg_hi)``.  Blocked BLAS distance computation keeps
    memory at ``block × n`` floats.
    """
    n = train.shape[0]
    sq = np.sum(train.astype(np.float32) ** 2, axis=1)
    pos = np.empty((n, pos_k), np.int32)
    neg = np.empty((n, neg_hi - neg_lo), np.int32)
    need = neg_hi + 1
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        d = sq[lo:hi, None] - 2.0 * (train[lo:hi] @ train.T) + sq[None, :]
        d[np.arange(hi - lo), np.arange(lo, hi)] = np.inf  # mask self
        part = np.argpartition(d, need, axis=1)[:, :need]
        order = np.argsort(np.take_along_axis(d, part, axis=1), axis=1)
        ranked = np.take_along_axis(part, order, axis=1)
        pos[lo:hi] = ranked[:, :pos_k]
        neg[lo:hi] = ranked[:, neg_lo:neg_hi]
    return pos, neg


def sample_triplets(rng: np.random.Generator, train: np.ndarray,
                    pos: np.ndarray, neg: np.ndarray, batch_idx: np.ndarray):
    """Draw (x, x⁺, x⁻) for a batch of training-row indices."""
    p_choice = pos[batch_idx, rng.integers(0, pos.shape[1], len(batch_idx))]
    n_choice = neg[batch_idx, rng.integers(0, neg.shape[1], len(batch_idx))]
    return train[batch_idx], train[p_choice], train[n_choice]


# ---------------------------------------------------------------------------
# Full training loop
# ---------------------------------------------------------------------------


def train_unq(train_data: np.ndarray, mcfg: M.ModelConfig, tcfg: TrainConfig,
              log_every: int = 200, log=print):
    """Train a UNQ model; returns (params, bn_state, history)."""
    t0 = time.time()
    rng = np.random.default_rng(tcfg.seed)
    key = jax.random.PRNGKey(tcfg.seed)
    train_data = np.ascontiguousarray(train_data, np.float32)
    n = train_data.shape[0]

    key, init_key = jax.random.split(key)
    sample = jnp.asarray(train_data[rng.choice(n, min(n, 4096), replace=False)])
    params, bn_state = M.init_params(init_key, mcfg, sample)
    opt_state = qhadam_init(params)

    if tcfg.use_triplet:
        log(f"[train] building neighbor table for {n} vectors ...")
        pos, neg = neighbor_table(train_data)
    else:
        pos = neg = np.zeros((n, 1), np.int32)

    history = []
    for step in range(tcfg.steps):
        batch_idx = rng.integers(0, n, tcfg.batch)
        x, xp, xn = sample_triplets(rng, train_data, pos, neg, batch_idx)
        key, sk = jax.random.split(key)
        params, bn_state, opt_state, metrics = train_step(
            params, bn_state, opt_state, sk,
            jnp.asarray(x), jnp.asarray(xp), jnp.asarray(xn),
            jnp.asarray(step), tcfg)
        if step % log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
            log(f"[train] step {step:5d}  loss={m['loss']:.4f}  "
                f"recon={m['recon']:.4f}  triplet={m['triplet']:.4f}  "
                f"cv2={m['cv2']:.4f}  perp={m['perplexity']:.1f}")
    log(f"[train] done in {time.time() - t0:.1f}s")
    return params, bn_state, history
