"""AOT export — train UNQ models and lower the inference graphs to HLO text.

This is the single entry point of the build-time Python path
(``make artifacts``).  For each named configuration it:

1. reads the canonical training split (fvecs written by ``unq gen-data``),
2. trains the UNQ model (``compile.train``),
3. folds BatchNorm and bakes the trained weights into three fixed-shape
   inference graphs — ``encode``, ``query_lut``, ``decode`` — each calling
   the Pallas kernels of :mod:`compile.kernels`,
4. lowers each graph to **HLO text** and writes
   ``artifacts/<name>/{encode,lut,decode}.hlo.txt`` + ``manifest.json``.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Python never runs after this step: the Rust runtime loads the HLO text via
``HloModuleProto::from_text_file`` and serves everything natively.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .vecs_io import read_fvecs

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
DATA_DIR = os.path.join(REPO_ROOT, "data")
ARTIFACT_DIR = os.path.join(REPO_ROOT, "artifacts")

# Scaled-down reproduction of the paper's training protocol (DESIGN.md §3):
# hidden 256 (paper: 1024), dc 128 (paper: 256), ~2500 steps on a 20k train
# subsample (paper: 500k) — knobs recorded in every manifest.
TRAIN_SUBSET = int(os.environ.get("UNQ_TRAIN_SUBSET", "20000"))
TRAIN_STEPS = int(os.environ.get("UNQ_TRAIN_STEPS", "2500"))
ABLATION_STEPS = int(os.environ.get("UNQ_ABLATION_STEPS", "2000"))


@dataclasses.dataclass(frozen=True)
class ExportConfig:
    """One artifact bundle: a dataset + byte budget + training variant."""

    name: str
    dataset: str            # dataset directory under data/ (train.fvecs)
    dim: int
    m: int                  # bytes/vector at K=256
    steps: int = TRAIN_STEPS
    variant: str = "unq"    # Table 5 ablation variant name
    hidden: int = 256
    dc: int = 128

    def model_config(self) -> M.ModelConfig:
        return M.ModelConfig(dim=self.dim, m=self.m, k=256, dc=self.dc,
                             hidden=self.hidden)

    def train_config(self) -> T.TrainConfig:
        v = self.variant
        return T.TrainConfig(
            steps=self.steps,
            use_triplet=v not in ("no_triplet",),
            recon_weight=0.0 if v == "triplet_only" else 1.0,
            alpha=1.0 if v == "triplet_only" else 0.01,
            use_hard=v != "wo_hard",
            use_gumbel=v != "wo_gumbel",
            use_cv_reg=v != "no_reg",
            seed=hash(self.name) % (2 ** 31),
        )


MAIN_CONFIGS = [
    ExportConfig("deep1m_8b", "deep1m", 96, 8),
    ExportConfig("deep1m_16b", "deep1m", 96, 16),
    ExportConfig("sift1m_8b", "sift1m", 128, 8),
    ExportConfig("sift1m_16b", "sift1m", 128, 16),
]

# Table 5 ablation variants (BigANN1M ≈ sift1m-sim, 8 bytes). "unq",
# "exhaustive rerank" and "no rerank" reuse the main sift1m_8b model —
# they differ only in the Rust-side search procedure.
ABLATION_CONFIGS = [
    ExportConfig("abl_no_triplet", "sift1m", 128, 8, ABLATION_STEPS, "no_triplet"),
    ExportConfig("abl_triplet_only", "sift1m", 128, 8, ABLATION_STEPS, "triplet_only"),
    ExportConfig("abl_wo_hard", "sift1m", 128, 8, ABLATION_STEPS, "wo_hard"),
    ExportConfig("abl_wo_gumbel", "sift1m", 128, 8, ABLATION_STEPS, "wo_gumbel"),
    ExportConfig("abl_no_reg", "sift1m", 128, 8, ABLATION_STEPS, "no_reg"),
]

ALL_CONFIGS = {c.name: c for c in MAIN_CONFIGS + ABLATION_CONFIGS}


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the trained weights are baked into the
    # graph as dense literals; the default elides them as `constant({...})`
    # which would NOT round-trip through the text parser.
    return comp.as_hlo_text(True)


def export_graph(fn, example_args, path: str) -> int:
    """Lower ``fn`` at the example shapes and write HLO text; returns size."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def load_train_split(cfg: ExportConfig, allow_synth: bool) -> np.ndarray:
    path = os.path.join(DATA_DIR, cfg.dataset, "train.fvecs")
    if os.path.exists(path):
        data = read_fvecs(path, limit=TRAIN_SUBSET)
        assert data.shape[1] == cfg.dim, (
            f"{path}: dim {data.shape[1]} != config dim {cfg.dim}")
        return data
    if not allow_synth:
        sys.exit(f"error: missing canonical train split {path}; run "
                 f"`make datasets` first (or pass --allow-synth for a "
                 f"self-generated distributional stand-in)")
    # Stand-in generator, used only for smoke runs. Mirrors the Rust
    # generators' *family* (deep-like: normalized random-ReLU-net GMM;
    # sift-like: non-negative heavy-tailed histograms).
    rng = np.random.default_rng(0xC0FFEE)
    n = TRAIN_SUBSET
    if cfg.dataset.startswith("deep"):
        lat = rng.normal(size=(n, 32)).astype(np.float32)
        centers = rng.normal(size=(64, 32)).astype(np.float32) * 1.5
        lat += centers[rng.integers(0, 64, n)]
        w1 = rng.normal(size=(32, 128)).astype(np.float32) / np.sqrt(32)
        w2 = rng.normal(size=(128, cfg.dim)).astype(np.float32) / np.sqrt(128)
        x = np.maximum(lat @ w1, 0) @ w2
        x /= np.linalg.norm(x, axis=1, keepdims=True) + 1e-9
        return x.astype(np.float32)
    scale = rng.gamma(2.0, 1.0, size=(n, cfg.dim // 8)).astype(np.float32)
    x = rng.exponential(1.0, size=(n, cfg.dim)).astype(np.float32)
    x *= np.repeat(scale, 8, axis=1)
    return np.minimum(np.floor(x * 12.0), 218.0).astype(np.float32)


def export_config(cfg: ExportConfig, allow_synth: bool, force: bool) -> None:
    out_dir = os.path.join(ARTIFACT_DIR, cfg.name)
    manifest_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(manifest_path) and not force:
        print(f"[aot] {cfg.name}: manifest exists, skipping (use --force)")
        return
    os.makedirs(out_dir, exist_ok=True)

    mcfg = cfg.model_config()
    tcfg = cfg.train_config()
    data = load_train_split(cfg, allow_synth)
    # Standardize per-dimension for training conditioning; the affine
    # transform is folded back into the exported graphs, which therefore
    # accept RAW vectors (critical for sift-like magnitudes ~0..218).
    mu = data.mean(axis=0)
    sigma = data.std(axis=0) + 1e-6
    data_std = (data - mu) / sigma
    print(f"[aot] {cfg.name}: training on {data.shape[0]}×{data.shape[1]} "
          f"(M={cfg.m}, variant={cfg.variant}, steps={tcfg.steps})")
    t0 = time.time()
    params, bn_state, history = T.train_unq(data_std, mcfg, tcfg)
    train_secs = time.time() - t0

    files = {}
    f32 = jnp.float32
    enc_spec = jax.ShapeDtypeStruct((mcfg.encode_batch, cfg.dim), f32)
    lut_spec = jax.ShapeDtypeStruct((mcfg.lut_batch, cfg.dim), f32)
    dec_spec = jax.ShapeDtypeStruct((mcfg.decode_batch, cfg.m), jnp.int32)
    for gname, fn, spec in [
        ("encode", M.export_encode_fn(params, bn_state, mcfg, mu, sigma), enc_spec),
        ("lut", M.export_lut_fn(params, bn_state, mcfg, mu, sigma), lut_spec),
        ("decode", M.export_decode_fn(params, bn_state, mcfg, mu, sigma), dec_spec),
    ]:
        path = os.path.join(out_dir, f"{gname}.hlo.txt")
        size = export_graph(fn, (spec,), path)
        files[gname] = os.path.basename(path)
        print(f"[aot]   wrote {path} ({size/1e6:.1f} MB)")

    n_params = mcfg.param_count(params)
    manifest = {
        "name": cfg.name,
        "dataset": cfg.dataset,
        "variant": cfg.variant,
        "dim": cfg.dim,
        "m": cfg.m,
        "k": mcfg.k,
        "dc": cfg.dc,
        "hidden": cfg.hidden,
        "bytes_per_vector": mcfg.bytes_per_vector,
        "encode_batch": mcfg.encode_batch,
        "lut_batch": mcfg.lut_batch,
        "decode_batch": mcfg.decode_batch,
        "files": files,
        "param_count": n_params,
        "param_bytes": n_params * 4,
        "train": {
            "subset": int(data.shape[0]),
            "steps": tcfg.steps,
            "batch": tcfg.batch,
            "alpha": tcfg.alpha,
            "seconds": round(train_secs, 1),
            "final_loss": history[-1]["loss"] if history else None,
            "final_perplexity": history[-1]["perplexity"] if history else None,
        },
        "history": history,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] {cfg.name}: manifest written "
          f"({n_params} params, {n_params * 4 / 1e6:.1f} MB fp32)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", nargs="*", default=None,
                    help="config names (default: the 4 main configs)")
    ap.add_argument("--ablation", action="store_true",
                    help="export the Table-5 ablation bundle instead")
    ap.add_argument("--allow-synth", action="store_true",
                    help="permit the in-python stand-in train split")
    ap.add_argument("--force", action="store_true",
                    help="re-train even if the manifest already exists")
    args = ap.parse_args()

    if args.configs:
        configs = [ALL_CONFIGS[n] for n in args.configs]
    elif args.ablation:
        configs = ABLATION_CONFIGS
    else:
        configs = MAIN_CONFIGS
    for cfg in configs:
        export_config(cfg, args.allow_synth, args.force)


if __name__ == "__main__":
    main()
