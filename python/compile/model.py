"""L2 — the UNQ model (paper §3.2–3.3) as a functional JAX program.

The model is a pair of fully-connected networks plus M learned codebooks:

* ``encoder``  x ∈ R^D → Linear→BN→ReLU ×2 → Linear head → (M, dc) — a point
  in the product of M learned codebook spaces (Figure 1, left→middle).
* ``codebooks`` C ∈ R^{M×K×dc} with learned per-codebook temperatures τ_m;
  codeword probabilities follow eq. (2).
* ``decoder``  concat of the M selected codewords → Linear→BN→ReLU ×2 →
  Linear → x̃ ∈ R^D (Figure 1, middle→right).

Everything is expressed over explicit parameter pytrees so the training
step (``compile.train``) is a pure jitted function, and export
(``compile.aot``) can fold BatchNorm into the linear layers and bake the
trained weights into the AOT inference graphs.  The inference graphs call
the Pallas kernels from :mod:`compile.kernels`; training uses the jnp
oracles (same math, pinned by tests) for CPU speed.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.encoder_block import mlp as pallas_mlp
from .kernels.heads import assign as pallas_assign
from .kernels.heads import heads_logits as pallas_heads_logits

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/architecture configuration of a UNQ model."""

    dim: int              # D — input descriptor dimensionality
    m: int                # number of codebooks (bytes per vector at K=256)
    k: int = 256          # codewords per codebook
    dc: int = 128         # codeword dimensionality (learned space)
    hidden: int = 256     # width of the two hidden layers
    encode_batch: int = 512   # fixed AOT batch for encode()
    lut_batch: int = 16       # fixed AOT batch for query_lut()
    decode_batch: int = 512   # fixed AOT batch for decode()

    @property
    def bytes_per_vector(self) -> int:
        assert self.k <= 256
        return self.m

    def param_count(self, params: Dict[str, Any]) -> int:
        return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_linear(key, d_in: int, d_out: int) -> Dict[str, jnp.ndarray]:
    """He-initialized linear layer."""
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / d_in)
    return {
        "w": jax.random.normal(wkey, (d_in, d_out), jnp.float32) * scale,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def _init_bn(d: int) -> Dict[str, jnp.ndarray]:
    return {
        "gamma": jnp.ones((d,), jnp.float32),
        "beta": jnp.zeros((d,), jnp.float32),
    }


def _init_bn_state(d: int) -> Dict[str, jnp.ndarray]:
    return {
        "mean": jnp.zeros((d,), jnp.float32),
        "var": jnp.ones((d,), jnp.float32),
    }


def init_params(key, cfg: ModelConfig,
                train_sample: jnp.ndarray | None = None):
    """Initialize (params, bn_state).

    If ``train_sample`` is given, codebooks are seeded from the encoder's
    initial head outputs on a data sample (k-means++-free variant: random
    data projections), which markedly speeds up convergence versus pure
    Gaussian init — the same trick shallow MCQ methods get from k-means.
    """
    keys = jax.random.split(key, 8)
    params = {
        "enc": [
            {**_init_linear(keys[0], cfg.dim, cfg.hidden), **_init_bn(cfg.hidden)},
            {**_init_linear(keys[1], cfg.hidden, cfg.hidden), **_init_bn(cfg.hidden)},
            _init_linear(keys[2], cfg.hidden, cfg.m * cfg.dc),
        ],
        "dec": [
            {**_init_linear(keys[3], cfg.m * cfg.dc, cfg.hidden), **_init_bn(cfg.hidden)},
            {**_init_linear(keys[4], cfg.hidden, cfg.hidden), **_init_bn(cfg.hidden)},
            _init_linear(keys[5], cfg.hidden, cfg.dim),
        ],
        "codebooks": jax.random.normal(
            keys[6], (cfg.m, cfg.k, cfg.dc), jnp.float32) / jnp.sqrt(cfg.dc),
        # τ_m, parameterized in log space for positivity (paper treats τ as
        # a regular trainable parameter).
        "log_tau": jnp.zeros((cfg.m,), jnp.float32),
    }
    bn_state = {
        "enc": [_init_bn_state(cfg.hidden), _init_bn_state(cfg.hidden)],
        "dec": [_init_bn_state(cfg.hidden), _init_bn_state(cfg.hidden)],
    }
    if train_sample is not None:
        h, _ = encoder_apply(params, bn_state, train_sample, train=False)
        # Seed each codebook with head outputs of random training points.
        n = h.shape[0]
        idx = jax.random.randint(keys[7], (cfg.m, cfg.k), 0, n)
        seeds = jnp.stack([h[idx[m_], m_, :] for m_ in range(cfg.m)])
        noise = jax.random.normal(keys[7], seeds.shape, jnp.float32) * 0.05
        params = {**params, "codebooks": seeds + noise}
    return params, bn_state


# ---------------------------------------------------------------------------
# Forward passes (training path: jnp refs; export path: Pallas kernels)
# ---------------------------------------------------------------------------


def _bn_apply(layer, state, x, train: bool):
    """BatchNorm forward; returns (y, new_state)."""
    if train:
        mean = jnp.mean(x, axis=0)
        var = jnp.var(x, axis=0)
        new_state = {
            "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + BN_EPS)
    y = (x - mean) * inv * layer["gamma"] + layer["beta"]
    return y, new_state


def _mlp_apply(layers, states, x, train: bool):
    """Linear→BN→ReLU ×(len-1) → Linear. Returns (y, new_states)."""
    h = x
    new_states: List[Dict[str, jnp.ndarray]] = []
    for i, layer in enumerate(layers[:-1]):
        h = ref.ref_linear_relu(h, layer["w"], layer["b"], relu=False)
        h, ns = _bn_apply(layer, states[i], h, train)
        new_states.append(ns)
        h = jnp.maximum(h, 0.0)
    out = layers[-1]
    h = ref.ref_linear_relu(h, out["w"], out["b"], relu=False)
    return h, new_states


def encoder_apply(params, bn_state, x, train: bool):
    """net(x): (B, D) → (B, M, dc) head outputs. Returns (h, new_bn)."""
    h, new_enc = _mlp_apply(params["enc"], bn_state["enc"], x, train)
    b = x.shape[0]
    m_dc = h.shape[1]
    m = params["codebooks"].shape[0]
    h = h.reshape(b, m, m_dc // m)
    return h, {**bn_state, "enc": new_enc}


def decoder_apply(params, bn_state, gathered, train: bool):
    """g(i): (B, M*dc) concatenated codewords → (B, D). Returns (x̃, bn)."""
    y, new_dec = _mlp_apply(params["dec"], bn_state["dec"], gathered, train)
    return y, {**bn_state, "dec": new_dec}


def logits_from_heads(params, h):
    """⟨net(x)_m, c_mk⟩ — the raw (un-tempered) scores of eq. (2)/(8)."""
    return ref.ref_heads_logits(h, params["codebooks"])


def encode(params, bn_state, x):
    """Hard encode f(x) (eq. 4): (B, D) → (B, M) int32 codes."""
    h, _ = encoder_apply(params, bn_state, x, train=False)
    return ref.ref_assign(h, params["codebooks"])


def decode_codes(params, bn_state, codes):
    """Reconstruct x̃ from int codes: (B, M) → (B, D)."""
    gathered = ref.ref_gather_codewords(codes, params["codebooks"])
    y, _ = decoder_apply(params, bn_state, gathered, train=False)
    return y


def query_lut(params, bn_state, q):
    """Per-query LUT for d2 (eq. 8): (B, D) → (B, M, K) dot products."""
    h, _ = encoder_apply(params, bn_state, q, train=False)
    return logits_from_heads(params, h)


def d2_from_lut(lut, codes):
    """d2(q, i) = -Σ_m lut[m, i_m] (the +const(q) term is rank-invariant)."""
    m_idx = jnp.arange(lut.shape[0])[None, :]
    return -jnp.sum(lut[m_idx, codes], axis=-1)


# ---------------------------------------------------------------------------
# BatchNorm folding + Pallas-kernel inference graphs (the AOT surface)
# ---------------------------------------------------------------------------


def fold_bn(layers, states) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Fold inference-mode BN into the preceding linear layer.

    ``y = ((x@w + b) - μ) · γ/√(σ²+ε) + β  =  x @ (w·s) + (b·s - μ·s + β)``
    with ``s = γ/√(σ²+ε)``.  Returns a list of plain ``(w, b)`` pairs
    consumable by the fused Pallas MLP kernel.
    """
    folded = []
    for i, layer in enumerate(layers[:-1]):
        s = layer["gamma"] * jax.lax.rsqrt(states[i]["var"] + BN_EPS)
        w = layer["w"] * s[None, :]
        b = (layer["b"] - states[i]["mean"]) * s + layer["beta"]
        folded.append((w, b))
    out = layers[-1]
    folded.append((out["w"], out["b"]))
    return folded


def fold_standardize(enc_layers, mu, sigma):
    """Fold input standardization ``x_std = (x − μ)/σ`` into the first
    folded encoder layer, so the AOT graphs accept raw vectors."""
    import jax.numpy as jnp
    (w0, b0), rest = enc_layers[0], enc_layers[1:]
    inv = 1.0 / jnp.asarray(sigma)
    w = w0 * inv[:, None]
    b = b0 - jnp.asarray(mu) @ w
    return [(w, b)] + list(rest)


def fold_unstandardize(dec_layers, mu, sigma):
    """Fold output un-standardization ``x = x_std·σ + μ`` into the final
    decoder layer."""
    import jax.numpy as jnp
    *rest, (wl, bl) = dec_layers
    sig = jnp.asarray(sigma)
    return list(rest) + [(wl * sig[None, :], bl * sig + jnp.asarray(mu))]


def export_encode_fn(params, bn_state, cfg: ModelConfig, mu=None, sigma=None):
    """Build the AOT ``encode`` graph: x (B,D) → codes (B,M) int32.

    Uses the Pallas fused-MLP and fused assign kernels so the exported HLO
    contains the L1 kernels.  ``mu``/``sigma`` fold train-time input
    standardization into the first layer (raw vectors in).
    """
    enc_layers = fold_bn(params["enc"], bn_state["enc"])
    if mu is not None:
        enc_layers = fold_standardize(enc_layers, mu, sigma)
    codebooks = params["codebooks"]

    def fn(x):
        h = pallas_mlp(x, enc_layers)
        h = h.reshape(x.shape[0], cfg.m, cfg.dc)
        return (pallas_assign(h, codebooks),)

    return fn


def export_lut_fn(params, bn_state, cfg: ModelConfig, mu=None, sigma=None):
    """Build the AOT ``query_lut`` graph: q (B,D) → lut (B,M,K) f32."""
    enc_layers = fold_bn(params["enc"], bn_state["enc"])
    if mu is not None:
        enc_layers = fold_standardize(enc_layers, mu, sigma)
    codebooks = params["codebooks"]

    def fn(q):
        h = pallas_mlp(q, enc_layers)
        h = h.reshape(q.shape[0], cfg.m, cfg.dc)
        return (pallas_heads_logits(h, codebooks),)

    return fn


def export_decode_fn(params, bn_state, cfg: ModelConfig, mu=None, sigma=None):
    """Build the AOT ``decode`` graph: codes (B,M) int32 → x̃ (B,D) f32."""
    dec_layers = fold_bn(params["dec"], bn_state["dec"])
    if mu is not None:
        dec_layers = fold_unstandardize(dec_layers, mu, sigma)
    codebooks = params["codebooks"]

    def fn(codes):
        gathered = ref.ref_gather_codewords(codes, codebooks)
        return (pallas_mlp(gathered, dec_layers),)

    return fn
