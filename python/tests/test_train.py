"""Training-protocol tests: schedules, optimizer, Gumbel-ST, loss, loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


def _tiny_data(n=600, d=24, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 2
    return (centers[rng.integers(0, 8, n)]
            + 0.3 * rng.normal(size=(n, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def test_one_cycle_shape():
    cfg = T.TrainConfig(steps=1000, lr=1e-3)
    lrs = [float(T.one_cycle_lr(cfg, jnp.asarray(s)))
           for s in [0, 150, 300, 650, 999]]
    assert lrs[0] == pytest.approx(cfg.lr / cfg.div_factor, rel=1e-3)
    assert lrs[2] == pytest.approx(cfg.lr, rel=1e-3)        # peak at warmup end
    assert lrs[2] > lrs[1] > lrs[0]                          # warming up
    assert lrs[2] > lrs[3] > lrs[4]                          # annealing
    assert lrs[4] == pytest.approx(cfg.lr / cfg.final_div, rel=0.05)


def test_beta_schedule_linear():
    cfg = T.TrainConfig(steps=101)
    assert float(T.beta_schedule(cfg, jnp.asarray(0))) == pytest.approx(1.0)
    assert float(T.beta_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.05)
    mid = float(T.beta_schedule(cfg, jnp.asarray(50)))
    assert 0.05 < mid < 1.0


# ---------------------------------------------------------------------------
# QHAdam
# ---------------------------------------------------------------------------


def test_qhadam_descends_quadratic():
    cfg = T.TrainConfig(lr=0.1)
    params = {"x": jnp.asarray(5.0)}
    opt = T.qhadam_init(params)
    for _ in range(200):
        grads = {"x": 2.0 * params["x"]}
        params, opt = T.qhadam_update(cfg, grads, opt, params, 0.1)
    assert abs(float(params["x"])) < 0.1


def test_qhadam_nu1_zero_is_plain_sgd_direction():
    # ν1=0, ν2=0 reduces the update to g / (|g| + eps): sign descent.
    cfg = T.TrainConfig(nu1=0.0, nu2=0.0)
    params = {"x": jnp.asarray(3.0)}
    opt = T.qhadam_init(params)
    new, _ = T.qhadam_update(cfg, {"x": jnp.asarray(4.0)}, opt, params, 0.5)
    assert float(new["x"]) == pytest.approx(3.0 - 0.5, rel=1e-4)


# ---------------------------------------------------------------------------
# Gumbel-Softmax ST
# ---------------------------------------------------------------------------


def test_gumbel_st_hard_is_onehot():
    key = jax.random.PRNGKey(0)
    log_p = jax.nn.log_softmax(jax.random.normal(key, (6, 3, 10)), axis=-1)
    y = T.gumbel_softmax_st(key, log_p, use_hard=True, use_gumbel=True)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, rtol=1e-5)
    assert np.allclose(np.sort(np.asarray(y), axis=-1)[..., :-1], 0.0, atol=1e-6)


def test_gumbel_st_soft_is_distribution():
    key = jax.random.PRNGKey(1)
    log_p = jax.nn.log_softmax(jax.random.normal(key, (4, 2, 8)), axis=-1)
    y = T.gumbel_softmax_st(key, log_p, use_hard=False, use_gumbel=True)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, rtol=1e-5)
    assert float(y.max()) < 1.0  # genuinely soft with overwhelming prob.


def test_no_gumbel_is_deterministic():
    key = jax.random.PRNGKey(2)
    log_p = jax.nn.log_softmax(jax.random.normal(key, (4, 2, 8)), axis=-1)
    y1 = T.gumbel_softmax_st(jax.random.PRNGKey(3), log_p, True, False)
    y2 = T.gumbel_softmax_st(jax.random.PRNGKey(4), log_p, True, False)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # hard assignment must equal plain argmax of log_p
    np.testing.assert_array_equal(np.asarray(y1.argmax(-1)),
                                  np.asarray(log_p.argmax(-1)))


def test_gumbel_st_gradient_flows():
    """Straight-through: d loss/d log_p must be nonzero despite hard fwd."""
    key = jax.random.PRNGKey(5)
    log_p = jax.nn.log_softmax(jax.random.normal(key, (2, 1, 6)), axis=-1)

    def f(lp):
        y = T.gumbel_softmax_st(key, lp, use_hard=True, use_gumbel=True)
        return jnp.sum(y * jnp.arange(6.0))

    g = jax.grad(f)(log_p)
    assert float(jnp.abs(g).sum()) > 0.0


# ---------------------------------------------------------------------------
# Neighbor tables / triplets
# ---------------------------------------------------------------------------


def test_neighbor_table_correctness():
    data = _tiny_data(n=300, d=8)
    pos, neg = T.neighbor_table(data, pos_k=3, neg_lo=50, neg_hi=60)
    assert pos.shape == (300, 3) and neg.shape == (300, 10)
    # Verify row 0 against a brute-force argsort.
    d = np.sum((data - data[0]) ** 2, axis=1)
    d[0] = np.inf
    order = np.argsort(d, kind="stable")
    got = set(pos[0].tolist())
    want = set(order[:3].tolist())
    # ties can permute equal-distance entries; compare distances instead
    np.testing.assert_allclose(sorted(d[list(got)]), sorted(d[list(want)]),
                               rtol=1e-5)
    assert (pos[0] != 0).all()  # self excluded


def test_neighbor_table_blocked_equals_unblocked():
    data = _tiny_data(n=257, d=6, seed=3)
    p1, n1 = T.neighbor_table(data, block=64)
    p2, n2 = T.neighbor_table(data, block=257)
    d = lambda i, idx: np.sum((data[idx] - data[i]) ** 2, -1)
    for i in [0, 100, 256]:
        np.testing.assert_allclose(sorted(d(i, p1[i])), sorted(d(i, p2[i])),
                                   rtol=1e-5)


def test_sample_triplets_shapes():
    data = _tiny_data(n=400, d=8)
    pos, neg = T.neighbor_table(data)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 400, 32)
    x, xp, xn = T.sample_triplets(rng, data, pos, neg, idx)
    assert x.shape == xp.shape == xn.shape == (32, 8)
    # positives must be nearer than negatives on average (true neighbors)
    dp = np.sum((x - xp) ** 2, -1).mean()
    dn = np.sum((x - xn) ** 2, -1).mean()
    assert dp < dn


# ---------------------------------------------------------------------------
# End-to-end training behaviour
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["unq", "no_triplet", "wo_hard",
                                     "wo_gumbel", "no_reg"])
def test_training_reduces_loss(variant):
    data = _tiny_data(n=500, d=24, seed=7)
    mcfg = M.ModelConfig(dim=24, m=4, k=32, dc=16, hidden=32)
    tcfg = T.TrainConfig(
        steps=60, batch=64,
        use_triplet=variant != "no_triplet",
        use_hard=variant != "wo_hard",
        use_gumbel=variant != "wo_gumbel",
        use_cv_reg=variant != "no_reg",
    )
    _, _, hist = T.train_unq(data, mcfg, tcfg, log_every=59, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["loss"])


def test_training_balances_codes():
    """The CV² regularizer should keep codeword perplexity well above 1."""
    data = _tiny_data(n=500, d=16, seed=9)
    mcfg = M.ModelConfig(dim=16, m=2, k=16, dc=8, hidden=32)
    tcfg = T.TrainConfig(steps=80, batch=64)
    _, _, hist = T.train_unq(data, mcfg, tcfg, log_every=79, log=lambda *_: None)
    assert hist[-1]["perplexity"] > 2.0
