"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (batch sizes that do and do not divide the block
targets, odd dims, degenerate K/M) and dtypes; assert_allclose against
ref.py is the core correctness signal of the build-time path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adc_scan, assign, heads_logits, linear_relu, ref

RTOL, ATOL = 1e-4, 1e-4


def rng_for(*shape_bits):
    return np.random.default_rng(abs(hash(shape_bits)) % (2**32))


# ---------------------------------------------------------------------------
# linear_relu
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 3, 16, 50, 128, 200, 256]),
    d=st.sampled_from([7, 32, 96, 128]),
    n=st.sampled_from([1, 17, 64, 256]),
    relu=st.booleans(),
)
def test_linear_relu_matches_ref(b, d, n, relu):
    rng = rng_for(b, d, n, relu)
    x = rng.normal(size=(b, d)).astype(np.float32)
    w = rng.normal(size=(d, n)).astype(np.float32)
    bias = rng.normal(size=(n,)).astype(np.float32)
    got = np.asarray(linear_relu(x, w, bias, relu=relu))
    want = np.asarray(ref.ref_linear_relu(x, w, bias, relu=relu))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([np.float32, np.float16]))
def test_linear_relu_dtypes(dtype):
    rng = rng_for(str(dtype))
    x = rng.normal(size=(32, 24)).astype(dtype)
    w = rng.normal(size=(24, 48)).astype(dtype)
    b = rng.normal(size=(48,)).astype(np.float32)
    got = np.asarray(linear_relu(x, w, b))
    want = np.asarray(ref.ref_linear_relu(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


def test_linear_relu_negative_clamped():
    x = -np.ones((4, 4), np.float32)
    w = np.eye(4, dtype=np.float32)
    b = np.zeros(4, np.float32)
    assert np.all(np.asarray(linear_relu(x, w, b)) == 0.0)
    assert np.all(np.asarray(linear_relu(x, w, b, relu=False)) == -1.0)


def test_linear_relu_shape_mismatch_raises():
    x = np.zeros((4, 5), np.float32)
    w = np.zeros((6, 7), np.float32)
    b = np.zeros(7, np.float32)
    with pytest.raises(AssertionError):
        linear_relu(x, w, b)


# ---------------------------------------------------------------------------
# heads_logits / assign
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 5, 64, 130]),
    m=st.sampled_from([1, 4, 8, 16]),
    k=st.sampled_from([16, 256]),
    dc=st.sampled_from([8, 64, 128]),
)
def test_heads_logits_matches_ref(b, m, k, dc):
    rng = rng_for(b, m, k, dc)
    h = rng.normal(size=(b, m, dc)).astype(np.float32)
    c = rng.normal(size=(m, k, dc)).astype(np.float32)
    got = np.asarray(heads_logits(h, c))
    want = np.asarray(ref.ref_heads_logits(h, c))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 7, 64, 129]),
    m=st.sampled_from([1, 8, 16]),
    k=st.sampled_from([4, 256]),
)
def test_assign_matches_ref(b, m, k):
    rng = rng_for(b, m, k, "assign")
    h = rng.normal(size=(b, m, 32)).astype(np.float32)
    c = rng.normal(size=(m, k, 32)).astype(np.float32)
    got = np.asarray(assign(h, c))
    want = np.asarray(ref.ref_assign(h, c))
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32
    assert got.min() >= 0 and got.max() < k


def test_assign_prefers_identical_codeword():
    # If a head output equals one codeword exactly (and others are tiny),
    # that codeword must win.
    m, k, dc = 2, 8, 4
    c = np.random.default_rng(3).normal(size=(m, k, dc)).astype(np.float32) * 0.01
    c[0, 5] = np.array([10, 0, 0, 0], np.float32)
    c[1, 2] = np.array([0, 10, 0, 0], np.float32)
    h = np.zeros((1, m, dc), np.float32)
    h[0, 0] = c[0, 5]
    h[0, 1] = c[1, 2]
    codes = np.asarray(assign(h, c))
    assert codes[0, 0] == 5 and codes[0, 1] == 2


# ---------------------------------------------------------------------------
# adc_scan
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 100, 1024, 3000]),
    m=st.sampled_from([1, 8, 16]),
    k=st.sampled_from([4, 256]),
    strategy=st.sampled_from(["gather", "onehot"]),
)
def test_adc_scan_matches_ref(n, m, k, strategy):
    rng = rng_for(n, m, k, strategy)
    codes = rng.integers(0, k, size=(n, m)).astype(np.int32)
    lut = rng.normal(size=(m, k)).astype(np.float32)
    got = np.asarray(adc_scan(codes, lut, strategy=strategy))
    want = np.asarray(ref.ref_adc_scan(codes, lut))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-3)


def test_adc_scan_identity_lut():
    # With a one-hot LUT row, the scan counts how many codes hit that slot.
    codes = np.array([[0, 1], [1, 1], [2, 1]], np.int32)
    lut = np.zeros((2, 4), np.float32)
    lut[0, 1] = 1.0
    lut[1, 1] = 1.0
    got = np.asarray(adc_scan(codes, lut))
    np.testing.assert_allclose(got, [1.0, 2.0, 1.0])


def test_adc_scan_strategies_agree_large():
    rng = rng_for("agree")
    codes = rng.integers(0, 256, size=(4096, 16)).astype(np.int32)
    lut = rng.normal(size=(16, 256)).astype(np.float32)
    a = np.asarray(adc_scan(codes, lut, strategy="gather"))
    b = np.asarray(adc_scan(codes, lut, strategy="onehot"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-3)
