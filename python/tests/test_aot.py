"""AOT export tests: fvecs I/O, config registry, HLO text emission."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import train as T
from compile.vecs_io import read_fvecs, read_ivecs, write_fvecs


def test_fvecs_roundtrip(tmp_path):
    x = np.random.default_rng(0).normal(size=(37, 12)).astype(np.float32)
    p = str(tmp_path / "x.fvecs")
    write_fvecs(p, x)
    np.testing.assert_array_equal(read_fvecs(p), x)
    np.testing.assert_array_equal(read_fvecs(p, limit=5), x[:5])


def test_fvecs_empty(tmp_path):
    p = str(tmp_path / "e.fvecs")
    open(p, "wb").close()
    assert read_fvecs(p).size == 0


def test_ivecs_read(tmp_path):
    gt = np.random.default_rng(1).integers(0, 1000, size=(9, 10)).astype(np.int32)
    p = str(tmp_path / "g.ivecs")
    out = np.empty((9, 11), np.int32)
    out[:, 0] = 10
    out[:, 1:] = gt
    out.tofile(p)
    np.testing.assert_array_equal(read_ivecs(p), gt)


def test_config_registry_consistent():
    assert len(aot.MAIN_CONFIGS) == 4
    assert len(aot.ABLATION_CONFIGS) == 5
    for c in aot.MAIN_CONFIGS + aot.ABLATION_CONFIGS:
        assert c.name in aot.ALL_CONFIGS
        mc = c.model_config()
        assert mc.bytes_per_vector == c.m
        tc = c.train_config()
        assert tc.steps > 0


def test_ablation_variant_switches():
    by_name = {c.name: c.train_config() for c in aot.ABLATION_CONFIGS}
    assert not by_name["abl_no_triplet"].use_triplet
    assert by_name["abl_triplet_only"].recon_weight == 0.0
    assert by_name["abl_triplet_only"].alpha == 1.0
    assert not by_name["abl_wo_hard"].use_hard
    assert not by_name["abl_wo_gumbel"].use_gumbel
    assert not by_name["abl_no_reg"].use_cv_reg


def test_hlo_text_contains_full_constants(tmp_path):
    """Weights must appear as dense literals (no elided `{...}` blobs)."""
    cfg = M.ModelConfig(dim=8, m=2, k=8, dc=4, hidden=8,
                        encode_batch=8, lut_batch=2, decode_batch=8)
    key = jax.random.PRNGKey(0)
    params, bn = M.init_params(key, cfg)
    path = str(tmp_path / "enc.hlo.txt")
    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    aot.export_graph(M.export_encode_fn(params, bn, cfg), (spec,), path)
    text = open(path).read()
    assert "constant({...})" not in text
    assert "ENTRY" in text
    # entry signature: one f32[8,8] parameter, s32[8,2] tuple result
    assert "f32[8,8]" in text and "s32[8,2]" in text


def test_exported_manifest_smoke(tmp_path, monkeypatch):
    """Full export_config run on a micro config with the synth fallback."""
    monkeypatch.setattr(aot, "ARTIFACT_DIR", str(tmp_path))
    monkeypatch.setattr(aot, "TRAIN_SUBSET", 400)
    cfg = aot.ExportConfig("t_micro", "deep_micro", 16, 2, steps=20)
    # micro model to keep the test fast
    monkeypatch.setattr(
        aot.ExportConfig, "model_config",
        lambda self: M.ModelConfig(dim=self.dim, m=self.m, k=16, dc=8,
                                   hidden=16, encode_batch=32, lut_batch=4,
                                   decode_batch=32))
    aot.export_config(cfg, allow_synth=True, force=True)
    import json
    man = json.load(open(tmp_path / "t_micro" / "manifest.json"))
    assert man["m"] == 2 and man["dim"] == 16
    for f in man["files"].values():
        assert (tmp_path / "t_micro" / f).exists()
    assert man["param_count"] > 0
    assert man["train"]["final_loss"] is not None
