"""L2 model tests: shapes, BN folding, export-vs-training-path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(dim=32, m=4, k=16, dc=8, hidden=24,
                    encode_batch=16, lut_batch=4, decode_batch=16)


@pytest.fixture(scope="module")
def params_state():
    key = jax.random.PRNGKey(0)
    sample = jax.random.normal(key, (64, CFG.dim), jnp.float32)
    return M.init_params(key, CFG, sample)


def test_encoder_shapes(params_state):
    params, bn = params_state
    x = jnp.ones((10, CFG.dim))
    h, _ = M.encoder_apply(params, bn, x, train=False)
    assert h.shape == (10, CFG.m, CFG.dc)


def test_encode_produces_valid_codes(params_state):
    params, bn = params_state
    x = jax.random.normal(jax.random.PRNGKey(1), (32, CFG.dim))
    codes = M.encode(params, bn, x)
    assert codes.shape == (32, CFG.m)
    assert codes.dtype == jnp.int32
    assert int(codes.min()) >= 0 and int(codes.max()) < CFG.k


def test_decode_shapes(params_state):
    params, bn = params_state
    codes = jnp.zeros((8, CFG.m), jnp.int32)
    x = M.decode_codes(params, bn, codes)
    assert x.shape == (8, CFG.dim)


def test_lut_matches_manual_logits(params_state):
    params, bn = params_state
    q = jax.random.normal(jax.random.PRNGKey(2), (4, CFG.dim))
    lut = M.query_lut(params, bn, q)
    assert lut.shape == (4, CFG.m, CFG.k)
    h, _ = M.encoder_apply(params, bn, q, train=False)
    manual = jnp.einsum("bmd,mkd->bmk", h, params["codebooks"])
    np.testing.assert_allclose(np.asarray(lut), np.asarray(manual),
                               rtol=1e-5, atol=1e-5)


def test_d2_consistency(params_state):
    """d2 computed via the LUT equals the negated logit sum at the codes."""
    params, bn = params_state
    q = jax.random.normal(jax.random.PRNGKey(3), (1, CFG.dim))
    x = jax.random.normal(jax.random.PRNGKey(4), (16, CFG.dim))
    codes = M.encode(params, bn, x)
    lut = M.query_lut(params, bn, q)[0]
    d2 = np.asarray(M.d2_from_lut(lut, codes))
    # manual: -sum_m <net(q)_m, c_{m,i_m}>
    h, _ = M.encoder_apply(params, bn, q, train=False)
    manual = np.zeros(16, np.float32)
    hq = np.asarray(h)[0]
    cb = np.asarray(params["codebooks"])
    cnp = np.asarray(codes)
    for i in range(16):
        manual[i] = -sum(hq[m_] @ cb[m_, cnp[i, m_]] for m_ in range(CFG.m))
    np.testing.assert_allclose(d2, manual, rtol=1e-4, atol=1e-4)


def test_bn_fold_equals_inference_bn(params_state):
    """Folded (w,b) stack == inference-mode BN forward, to float tolerance."""
    params, bn = params_state
    x = jax.random.normal(jax.random.PRNGKey(5), (12, CFG.dim))
    h_ref, _ = M.encoder_apply(params, bn, x, train=False)
    folded = M.fold_bn(params["enc"], bn["enc"])
    h = x
    for i, (w, b) in enumerate(folded):
        h = h @ w + b
        if i < len(folded) - 1:
            h = jnp.maximum(h, 0.0)
    h = h.reshape(12, CFG.m, CFG.dc)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_export_fns_match_reference_paths(params_state):
    params, bn = params_state
    x = jax.random.normal(jax.random.PRNGKey(6), (CFG.encode_batch, CFG.dim))
    enc = M.export_encode_fn(params, bn, CFG)(x)[0]
    np.testing.assert_array_equal(np.asarray(enc),
                                  np.asarray(M.encode(params, bn, x)))
    q = x[: CFG.lut_batch]
    lut = M.export_lut_fn(params, bn, CFG)(q)[0]
    np.testing.assert_allclose(np.asarray(lut),
                               np.asarray(M.query_lut(params, bn, q)),
                               rtol=1e-4, atol=1e-4)
    codes = M.encode(params, bn, x)[: CFG.decode_batch]
    dec = M.export_decode_fn(params, bn, CFG)(codes)[0]
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(M.decode_codes(params, bn, codes)),
                               rtol=1e-3, atol=1e-3)


def test_param_count_positive(params_state):
    params, _ = params_state
    n = CFG.param_count(params)
    # enc: 32*24+24 + 24*24+24 + 24*32+32 (+2*2*24 bn) ; dec sym; codebooks 4*16*8
    assert n > 4 * 16 * 8
    assert isinstance(n, int)


def test_reconstruction_better_than_random(params_state):
    """Even untrained, decode(encode(x)) should beat a random codes baseline
    after a few training steps — here we only check it is finite and shaped."""
    params, bn = params_state
    x = jax.random.normal(jax.random.PRNGKey(7), (16, CFG.dim))
    rec = M.decode_codes(params, bn, M.encode(params, bn, x))
    assert bool(jnp.isfinite(rec).all())


def test_standardization_folding_matches_explicit():
    """Folded (μ,σ) first/last layers must equal explicit standardize →
    model → unstandardize (the raw-vector AOT contract)."""
    import numpy as np
    key = jax.random.PRNGKey(8)
    params, bn = M.init_params(key, CFG)
    mu = np.arange(CFG.dim, dtype=np.float32) * 0.1
    sigma = 1.0 + 0.05 * np.arange(CFG.dim, dtype=np.float32)
    x_raw = np.asarray(jax.random.normal(key, (CFG.encode_batch, CFG.dim))) * sigma + mu
    x_raw = jnp.asarray(x_raw.astype(np.float32))
    x_std = (x_raw - mu) / sigma

    enc_folded = M.export_encode_fn(params, bn, CFG, mu, sigma)(x_raw)[0]
    enc_explicit = M.encode(params, bn, x_std)
    np.testing.assert_array_equal(np.asarray(enc_folded),
                                  np.asarray(enc_explicit))

    lut_folded = M.export_lut_fn(params, bn, CFG, mu, sigma)(x_raw[:CFG.lut_batch])[0]
    lut_explicit = M.query_lut(params, bn, x_std[:CFG.lut_batch])
    np.testing.assert_allclose(np.asarray(lut_folded),
                               np.asarray(lut_explicit), rtol=2e-3, atol=2e-3)

    codes = enc_explicit[:CFG.decode_batch]
    dec_folded = M.export_decode_fn(params, bn, CFG, mu, sigma)(codes)[0]
    dec_explicit = np.asarray(M.decode_codes(params, bn, codes)) * sigma + mu
    np.testing.assert_allclose(np.asarray(dec_folded), dec_explicit,
                               rtol=2e-3, atol=2e-3)
